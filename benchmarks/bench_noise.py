"""Paper Fig. 9: noise-aware fine-tuning restores accuracy under ReRAM
non-idealities. Three conditions on a real (small) model + synthetic task:

  ideal        train clean,  eval clean   (no crossbar noise)
  naive        train clean,  eval noisy   (deploy on non-ideal crossbars)
  noise-aware  train noisy,  eval noisy   (the paper's method)

Claim: noise-aware recovers to within ~0.5% of ideal."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.core.noise import NoiseConfig, apply_weight_noise
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.steps import TrainHParams, make_train_step

SIGMA = 0.03
STEPS = 120


def _train(cfg, params, ds, noise_cfg, seed=0):
    ec = tfm.ExecConfig(noise=noise_cfg)
    step = jax.jit(make_train_step(cfg, ec, TrainHParams(
        adamw=AdamWConfig(lr=5e-3))))
    lora = lora_lib.init_lora_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(lora)
    rng = jax.random.PRNGKey(seed + 1)
    for i in range(STEPS):
        b = ds.batch(i, 16, 64)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        lora, opt, m = step(params, lora, opt, batch,
                            jax.random.fold_in(rng, i))
    return lora


def _eval_acc(cfg, params, lora, ds, noisy: bool, seed=7):
    if noisy:  # perturb the frozen base the way a non-ideal crossbar would
        nc = NoiseConfig(enabled=True, sigma_rel=SIGMA)
        key = jax.random.PRNGKey(seed)

        def pert(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if isinstance(x, jax.Array) and x.ndim >= 2 and x.size > 4096:
                return apply_weight_noise(x, nc, jax.random.fold_in(
                    key, hash(jax.tree_util.keystr(path)) % (2**31)))
            return x
        params = jax.tree_util.tree_map_with_path(pert, params)
    accs = []
    for i in range(5):
        b = ds.batch(10_000 + i, 16, 64)
        lg, _, _ = tfm.forward(cfg, params, {"tokens": jnp.asarray(b["tokens"])},
                               lora=lora, mode="train")
        accs.append(float(jnp.mean(jnp.argmax(lg, -1) ==
                                   jnp.asarray(b["labels"]))))
    return float(np.mean(accs))


def run():
    cfg = reduce_config(get_config("paper-gpt2-medium"), n_periods=2,
                        d_model=128, n_heads=4, d_ff=512)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg.vocab_size, seed=5)

    lora_clean = _train(cfg, params, ds, NoiseConfig(enabled=False))
    lora_noisy = _train(cfg, params, ds,
                        NoiseConfig(enabled=True, sigma_rel=SIGMA))

    ideal = _eval_acc(cfg, params, lora_clean, ds, noisy=False)
    naive = _eval_acc(cfg, params, lora_clean, ds, noisy=True)
    aware = _eval_acc(cfg, params, lora_noisy, ds, noisy=True)
    payload = {"sigma_rel": SIGMA, "ideal_acc": ideal, "naive_acc": naive,
               "noise_aware_acc": aware,
               "gap_naive_pct": 100 * (ideal - naive),
               "gap_aware_pct": 100 * (ideal - aware)}
    emit("fig9_noise", 0.0,
         f"ideal={ideal:.4f}_naive={naive:.4f}_aware={aware:.4f}")
    save_json("fig9_noise_aware", payload)
    return payload


if __name__ == "__main__":
    run()
