"""Shared benchmark utilities: timing + CSV emission + result storage."""
import json
import pathlib
import time

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "paper"
OUT.mkdir(parents=True, exist_ok=True)

ROWS = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload) -> None:
    (OUT / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=str))


def timed(fn, *args, n=3, **kw):
    fn(*args, **kw)          # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / n
    return out, dt * 1e6


PAPER_MODELS = {
    "roberta-base": dict(n_layers=12, d_model=768, n=512),
    "bert-large": dict(n_layers=24, d_model=1024, n=512),
    "gpt2-medium": dict(n_layers=24, d_model=1024, n=1024),
    "bloom-560m": dict(n_layers=24, d_model=1024, n=2048),
}
