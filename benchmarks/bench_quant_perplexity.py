"""Paper Fig. 13: model perplexity under MnFm crossbar-wise quantization.

Protocol mirrors the paper: start from a *pretrained* base (we pretrain a
small LM on the synthetic corpus since there's no internet), quantize it
crossbar-wise at each MnFm config, LoRA-fine-tune on the task, and measure
eval perplexity. Expected ordering: bf16 ≈ M8F8 <= M8F4 < M4F8 << M4F4."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import lora as lora_lib, quant
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.steps import TrainHParams, make_train_step

PRETRAIN_STEPS = 250
FT_STEPS = 80
CONFIGS = {"bf16": None, "M8F8": (8, 8), "M8F4": (8, 4), "M4F8": (4, 8),
           "M4F4": (4, 4)}


def _pretrain(cfg, ds, seed=0):
    """Full pretraining of the small base (AdamW over all params)."""
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    oc = AdamWConfig(lr=2e-3)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            lg, _, _ = tfm.forward(cfg, p, {"tokens": batch["tokens"]},
                                   mode="train")
            return tfm.lm_loss(cfg, lg, batch["labels"])[0]
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw.apply_updates(oc, params, g, opt)
        return params, opt, loss

    for i in range(PRETRAIN_STEPS):
        b = ds.batch(i, 16, 64)
        params, opt, loss = step(params, opt,
                                 {k: jnp.asarray(v) for k, v in b.items()})
    return params, float(loss)


def _finetune_and_ppl(cfg, base, ds, seed=1):
    step = jax.jit(make_train_step(cfg, tfm.ExecConfig(),
                                   TrainHParams(adamw=AdamWConfig(lr=3e-3))))
    lora = lora_lib.init_lora_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(lora)
    rng = jax.random.PRNGKey(seed + 1)
    for i in range(FT_STEPS):
        b = ds.batch(1000 + i, 16, 64)
        lora, opt, _ = step(base, lora, opt,
                            {k: jnp.asarray(v) for k, v in b.items()},
                            jax.random.fold_in(rng, i))
    # eval perplexity
    nll = []
    for i in range(5):
        b = ds.batch(20_000 + i, 16, 64)
        lg, _, _ = tfm.forward(cfg, base, {"tokens": jnp.asarray(b["tokens"])},
                               lora=lora, mode="train")
        loss, _ = tfm.lm_loss(cfg, lg, jnp.asarray(b["labels"]))
        nll.append(float(loss))
    return float(np.exp(np.mean(nll)))


def run():
    cfg = reduce_config(get_config("paper-gpt2-medium"), n_periods=2,
                        d_model=128, n_heads=4, d_ff=512)
    ds = SyntheticLM(cfg.vocab_size, seed=2)
    base, pre_loss = _pretrain(cfg, ds)
    payload = {"pretrain_final_loss": pre_loss, "ppl": {}}
    for tag, bits in CONFIGS.items():
        if bits is None:
            qbase = base
        else:
            qbase = quant.quantize_params(
                base, QuantConfig(mha_bits=bits[0], ff_bits=bits[1]),
                min_size=1)
        ppl = _finetune_and_ppl(cfg, qbase, ds)
        payload["ppl"][tag] = ppl
        emit(f"fig13_ppl_{tag}", 0.0, f"ppl={ppl:.3f}")
    p = payload["ppl"]
    payload["ordering_ok"] = bool(p["M8F8"] <= p["M8F4"] * 1.02 <= p["M4F4"] * 1.02
                                  and p["M4F4"] >= p["M8F8"])
    emit("fig13_ordering", 0.0,
         f"bf16={p['bf16']:.2f}_M8F8={p['M8F8']:.2f}_M8F4={p['M8F4']:.2f}"
         f"_M4F8={p['M4F8']:.2f}_M4F4={p['M4F4']:.2f}")
    save_json("fig13_quant_perplexity", payload)
    return payload


if __name__ == "__main__":
    run()
