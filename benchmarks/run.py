"""Benchmark driver: one module per paper table/figure plus system benches.
Prints ``name,us_per_call,derived`` CSV rows; JSON artifacts under
experiments/paper/ (plus a consolidated BENCH_SUMMARY.json).

``--smoke`` (or BENCH_SMOKE=1) shrinks workloads for CI: modules read the
env var, so the flag works however the driver is invoked.
"""
import argparse
import json
import os
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workloads for CI")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"

    from benchmarks import (bench_compute_breakdown, bench_end2end,
                            bench_kernel_complexity, bench_kernels,
                            bench_noc, bench_noise, bench_pipeline_stages,
                            bench_quant_energy, bench_quant_perplexity,
                            bench_serve_throughput, bench_systolic_config)
    from benchmarks import common
    mods = [
        ("tableII", bench_kernel_complexity),
        ("fig6_systolic", bench_systolic_config),
        ("fig7_breakdown", bench_compute_breakdown),
        ("fig8_noc", bench_noc),
        ("fig9_noise", bench_noise),
        ("fig10_pipeline", bench_pipeline_stages),
        ("fig11_15_end2end", bench_end2end),
        ("fig12_14_quant_energy", bench_quant_energy),
        ("fig13_quant_ppl", bench_quant_perplexity),
        ("kernels", bench_kernels),
        ("serve_throughput", bench_serve_throughput),
    ]
    if args.only:
        mods = [(n, m) for n, m in mods if n == args.only]
        if not mods:
            sys.exit(f"unknown benchmark {args.only!r}")
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
    summary = {"smoke": os.environ.get("BENCH_SMOKE", "0") == "1",
               "failures": failures,
               "rows": [{"name": n, "us_per_call": u, "derived": d}
                        for n, u, d in common.ROWS]}
    (common.OUT / "BENCH_SUMMARY.json").write_text(
        json.dumps(summary, indent=1))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
