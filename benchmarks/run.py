"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; JSON artifacts under
experiments/paper/."""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_compute_breakdown, bench_end2end,
                            bench_kernel_complexity, bench_kernels,
                            bench_noc, bench_noise, bench_pipeline_stages,
                            bench_quant_energy, bench_quant_perplexity,
                            bench_systolic_config)
    mods = [
        ("tableII", bench_kernel_complexity),
        ("fig6_systolic", bench_systolic_config),
        ("fig7_breakdown", bench_compute_breakdown),
        ("fig8_noc", bench_noc),
        ("fig9_noise", bench_noise),
        ("fig10_pipeline", bench_pipeline_stages),
        ("fig11_15_end2end", bench_end2end),
        ("fig12_14_quant_energy", bench_quant_energy),
        ("fig13_quant_ppl", bench_quant_perplexity),
        ("kernels", bench_kernels),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
