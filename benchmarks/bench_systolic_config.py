"""Paper Fig. 6: systolic grid search — PE count & aspect ratio vs the
ReRAM pipeline-stage delay; 4096 PEs at 128x32 should win."""
from benchmarks.common import PAPER_MODELS, emit, save_json
from repro.perfmodel import atleus as hw, pipeline as pipe
from repro.perfmodel.atleus import TransformerDims

GRIDS = [(32, 32), (64, 32), (32, 64), (128, 32), (64, 64), (32, 128),
         (128, 64), (256, 16)]


def run():
    payload = {}
    for name in ("bert-large", "gpt2-medium"):
        d = TransformerDims(name, **PAPER_MODELS[name])
        # reference: the slowest ReRAM stage at the paper's M8F8 deployment
        reram_stage = max(
            hw.reram_matmul_time(d.d_model, 4 * d.d_model, d.n, weight_bits=8,
                                 cores=16, layers_resident=d.n_layers,
                                 dequant=True),
            hw.reram_matmul_time(d.ff, d.d_model, d.n, weight_bits=8,
                                 cores=16, layers_resident=d.n_layers,
                                 dequant=True))
        rows = {}
        for (r, c) in GRIDS:
            # fine-tuning: attention fwd + backward (2 more matmuls each)
            t = 3 * (hw.systolic_matmul_time(d.n, d.d_model, d.n, rows=r,
                                             cols=c, cores=16)
                     + hw.systolic_matmul_time(d.n, d.n, d.d_model, rows=r,
                                               cols=c, cores=16))
            t += hw.softmax_time(d.n, d.n)
            for _ in range(d.lora_k):   # LoRA A (n,d,r) and B (n,r,d)
                t += 2 * (hw.systolic_matmul_time(d.n, d.d_model, d.lora_r,
                                                  rows=r, cols=c, cores=16)
                          + hw.systolic_matmul_time(d.n, d.lora_r, d.d_model,
                                                    rows=r, cols=c, cores=16))
            util = hw.systolic_utilization(d.n, d.d_model, d.lora_r, r, c)
            rows[f"{r}x{c}"] = {"pes": r * c,
                                "delay_norm": t / reram_stage,
                                "lora_util": util}
        payload[name] = rows
        # the paper's finding: <4096 PEs can't fit in one stage; among the
        # 4096-PE shapes our analytical model puts 128x32 and 64x64 within
        # ~6% (SCALE-sim's finer pipeline modeling selects 128x32).
        fits = sorted((g for g, v in rows.items() if v["delay_norm"] <= 1.0),
                      key=lambda g: rows[g]["pes"])
        min_pes = rows[fits[0]]["pes"] if fits else None
        payload[name + "__finding"] = {
            "min_pes_fitting": min_pes,
            "fits_128x32": "128x32" in fits,
            "smaller_grids_fail": all(rows[g]["delay_norm"] > 1.0
                                      for g in rows if rows[g]["pes"] < 4096),
        }
        emit(f"systolic_{name}", 0.0,
             f"min_fitting_pes={min_pes}_128x32_fits={'128x32' in fits}"
             f"_delay128x32={rows['128x32']['delay_norm']:.2f}")
    save_json("fig6_systolic_grid", payload)
    return payload


if __name__ == "__main__":
    run()
