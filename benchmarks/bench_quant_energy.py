"""Paper Figs. 12 & 14: system energy under MnFm quantization, normalized
to each architecture's 16-bit implementation. Atleus decreases (slope < 1);
GPU / 3D-TPU / HAIMA increase (dequantize-before-compute)."""
from benchmarks.common import PAPER_MODELS, emit, save_json
from repro.perfmodel import baselines as bl
from repro.perfmodel.atleus import TransformerDims


def run():
    payload = {}
    for name in ("gpt2-medium", "bloom-560m"):
        d = TransformerDims(name, **PAPER_MODELS[name])
        tr = bl.quant_energy_trend(d)
        payload[name] = tr
        for tag, row in tr.items():
            emit(f"quant_energy_{name}_{tag}", 0.0,
                 "_".join(f"{k}={v:.2f}" for k, v in row.items()))
        # paper invariants
        assert tr["M8F4"]["atleus"] < tr["M4F8"]["atleus"], \
            "FF quantization must save more than MHA (2x params)"
        assert all(tr[t]["gpu"] > 1.0 for t in tr if t != "M16F16")
        assert all(tr[t]["atleus"] < 1.0 for t in tr if t != "M16F16")
    save_json("fig12_14_quant_energy", payload)
    return payload


if __name__ == "__main__":
    run()
