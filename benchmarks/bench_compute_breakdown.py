"""Paper Fig. 7 + Eq. 5 + Table II: ReRAM vs systolic compute/energy
breakdown — analytic AND traced from the model as built."""
import jax
import jax.numpy as jnp

from benchmarks.common import PAPER_MODELS, emit, save_json, timed
from repro.configs import get_config, reduce_config
from repro.core import hetero, lora as lora_lib
from repro.models import transformer as tfm
from repro.perfmodel import pipeline as pipe
from repro.perfmodel.atleus import TransformerDims, reram_share


def run():
    payload = {}
    # --- analytic Eq. 5 across the paper's models ---
    for name, dims in PAPER_MODELS.items():
        d = TransformerDims(name, **dims)
        share = reram_share(d)
        e = pipe.atleus_layer_energy(d)
        payload[name] = {
            "reram_share_pct": share * 100,
            "ratio": share / (1 - share),
            "ratio_12d_over_n": 12 * d.d_model / d.n,
            "energy_reram_pct": 100 * e["reram"] / (e["reram"] + e["systolic"]),
        }
        emit(f"eq5_share_{name}", 0.0,
             f"reram={share*100:.1f}%_paper=90.1-94.7%")

    # --- traced from the real model (GPT-2M shaped, reduced depth) ---
    cfg = reduce_config(get_config("paper-gpt2-medium"), n_periods=2,
                        d_model=256, n_heads=8, d_ff=1024)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    lora = lora_lib.init_lora_params(cfg, jax.random.PRNGKey(1))
    toks = {"tokens": jnp.zeros((1, 256), jnp.int32)}

    def fwd(p, l):
        return tfm.forward(cfg, p, toks, lora=l, mode="train")[0]

    # NOTE: no timing wrapper here — jax.eval_shape caches traces, and the
    # tally is populated by Python side effects during tracing.
    rep = hetero.breakdown_of(fwd, params, lora)
    us = 0.0
    payload["traced_gpt2m_reduced"] = {
        "static_share_pct": rep.static_share * 100,
        "static_flops": rep.static_flops,
        "dynamic_flops": rep.dynamic_flops,
    }
    emit("traced_static_share", us,
         f"static={rep.static_share*100:.1f}%_dynamic={100-rep.static_share*100:.1f}%")
    save_json("fig7_compute_breakdown", payload)
    return payload


if __name__ == "__main__":
    run()
