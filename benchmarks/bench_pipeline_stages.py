"""Paper Fig. 10: per-stage compute+comm delays, Atleus vs HAIMA
(BERT-Large, n=512) + quantized-stage speedup (SS IV.D duplication)."""
from benchmarks.common import PAPER_MODELS, emit, save_json
from repro.perfmodel import pipeline as pipe
from repro.perfmodel.atleus import TransformerDims


def run():
    d = TransformerDims("bert-large", **PAPER_MODELS["bert-large"])
    at = pipe.atleus_stages(d)
    ha = pipe.haima_stages(d)
    at8 = pipe.atleus_stages(d, mha_bits=8, ff_bits=8)
    payload = {}
    for s in ("S1", "S2", "S3", "S4"):
        payload[s] = {
            "atleus_compute_us": at.compute[s] * 1e6,
            "atleus_comm_us": at.comm[s] * 1e6,
            "haima_compute_us": ha.compute[s] * 1e6,
            "haima_comm_us": ha.comm[s] * 1e6,
            "atleus_m8f8_us": at8.total(s) * 1e6,
        }
        emit(f"fig10_{s}", 0.0,
             f"atleus={at.total(s)*1e6:.0f}us_haima={ha.total(s)*1e6:.0f}us")
    payload["bottleneck_ratio_haima_over_atleus"] = ha.bottleneck / at.bottleneck
    payload["quantized_bottleneck_speedup"] = at.bottleneck / at8.bottleneck
    emit("fig10_bottleneck", 0.0,
         f"haima/atleus={ha.bottleneck/at.bottleneck:.1f}x_m8f8_speedup="
         f"{at.bottleneck/at8.bottleneck:.2f}x")
    save_json("fig10_pipeline_stages", payload)
    return payload


if __name__ == "__main__":
    run()
