"""Serving throughput: paged arena + chunked prefill vs the dense
``max_batch x max_len`` baseline, at 16+ concurrent mixed-length requests
and 4 LoRA adapters hot (paper SS V.G multi-task serving).

Reports decode tokens/s (steady-state, measured on a second pass so every
jit signature is warm), per-request p50/p99 completion latency, KV arena
bytes, and the engine's compile accounting (the paged step must compile
once per (chunk-bucket, table-width-bucket) pair, never per prompt length).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import kvcache
from repro.models.transformer import init_params
from repro.serve.engine import PagedServeEngine, Request, ServeEngine


def _requests(n, vocab, rng, max_new):
    reqs = []
    for i in range(n):
        plen = int(rng.integers(6, 64))
        reqs.append(dict(uid=i,
                         prompt=rng.integers(0, vocab, plen).astype(np.int32),
                         max_new_tokens=max_new, adapter_id=i % 4))
    return reqs


def _drive(make_engine, reqs):
    """Two passes over ONE engine instance (per-instance jax.jit caches):
    pass 1 warms every jit signature — greedy decode is deterministic, so
    the measured pass re-hits exactly the same shapes — pass 2 measures
    wall time and per-request completion latency."""
    eng = make_engine()

    def one_pass(uid_off):
        for r in reqs:
            eng.submit(Request(**{**r, "uid": r["uid"] + uid_off}))
        t0 = time.perf_counter()
        done_at = {}
        ticks = 0
        while (eng.queue or (eng.sched.active() if hasattr(eng, "sched")
                             else any(eng.slot_req))) and ticks < 100_000:
            eng.step()
            ticks += 1
            now = time.perf_counter() - t0
            for uid in eng.finished:
                if uid >= uid_off:
                    done_at.setdefault(uid, now)
        wall = time.perf_counter() - t0
        total_new = sum(len(r.generated) for u, r in eng.finished.items()
                        if u >= uid_off)
        lats = np.asarray([done_at[u] for u in sorted(done_at)])
        return dict(wall_s=wall, ticks=ticks, new_tokens=total_new,
                    tok_per_s=total_new / wall,
                    p50_s=float(np.percentile(lats, 50)),
                    p99_s=float(np.percentile(lats, 99)))

    one_pass(0)                      # warm-up: compiles every signature
    return eng, one_pass(100_000)    # measured: warm jit caches


def run():
    smoke = os.environ.get("BENCH_SMOKE", "0") == "1"
    cfg = reduce_config(get_config("llama3.2-1b"))
    n_req, max_new = (16, 8) if smoke else (24, 24)
    max_len, max_slots, page = (256, 16, 16) if smoke else (1024, 16, 16)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i + 1))
                for i in range(4)]
    rng = np.random.default_rng(0)
    reqs = _requests(n_req, cfg.vocab_size, rng, max_new)
    # pool sized for the mixed traffic, a fraction of the dense arena
    num_pages = max_slots * (64 + max_new + page) // page

    dense_eng, dense = _drive(
        lambda: ServeEngine(cfg, params, adapters=adapters,
                            max_batch=max_slots, max_len=max_len), reqs)
    paged_eng, paged = _drive(
        lambda: PagedServeEngine(cfg, params, adapters=adapters,
                                 max_slots=max_slots, max_len=max_len,
                                 page_size=page, num_pages=num_pages,
                                 prefill_chunk=32), reqs)

    stats = paged_eng.stats()
    speedup = paged["tok_per_s"] / dense["tok_per_s"]
    dense_bytes = kvcache.cache_bytes(dense_eng.cache)
    paged_bytes = kvcache.cache_bytes(paged_eng.cache)
    max_sigs = (len(paged_eng.chunk_buckets) * len(paged_eng.block_buckets))
    bucketed = stats["compiled_steps"] <= max_sigs
    assert bucketed, (stats["step_signatures"], max_sigs)
    assert stats["jit_cache_size"] == stats["compiled_steps"], stats

    emit("serve_dense", dense["wall_s"] * 1e6 / max(dense["ticks"], 1),
         f"tok/s={dense['tok_per_s']:.1f}_p99={dense['p99_s']*1e3:.0f}ms")
    emit("serve_paged", paged["wall_s"] * 1e6 / max(paged["ticks"], 1),
         f"tok/s={paged['tok_per_s']:.1f}_p99={paged['p99_s']*1e3:.0f}ms")
    emit("serve_speedup", 0.0,
         f"{speedup:.2f}x_decode_throughput_"
         f"{'PASS' if speedup >= 2 else 'BELOW'}_2x_target_"
         f"kv_bytes_{dense_bytes/max(paged_bytes,1):.1f}x_smaller")

    payload = {
        "smoke": smoke,
        "workload": {"n_requests": n_req, "adapters": 4,
                     "prompt_lens": "6..64 mixed", "max_new": max_new,
                     "max_len": max_len, "max_slots": max_slots},
        "dense": {**dense, "kv_bytes": dense_bytes},
        "paged": {**paged, "kv_bytes": paged_bytes,
                  "page_size": page, "num_pages": num_pages,
                  "compiled_steps": stats["compiled_steps"],
                  "step_signatures": [list(s) for s in
                                      stats["step_signatures"]],
                  "max_signatures": max_sigs,
                  "preemptions": stats["preemptions"],
                  "peak_pages": stats["peak_pages"]},
        "decode_throughput_speedup": speedup,
        "meets_2x_target": bool(speedup >= 2),
    }
    save_json("serve_throughput", payload)
    return payload


if __name__ == "__main__":
    run()
