"""Serving throughput: paged arena + chunked prefill vs the dense
``max_batch x max_len`` baseline, at 16+ concurrent mixed-length requests
and 4 LoRA adapters hot (paper SS V.G multi-task serving).

Reports decode tokens/s (steady-state, measured on a second pass so every
jit signature is warm), per-request p50/p99 completion latency, KV arena
bytes, and the engine's compile accounting (the paged step must compile
once per (chunk-bucket, table-width-bucket) pair, never per prompt length).

A second, shared-prefix workload (N requests drawn from a handful of
prompt families — the system-prompt serving pattern) measures the
copy-on-write prefix cache: prefill tokens actually computed, prefix-hit
rate, CoW forks, and peak KV pages vs the same paged engine with the
cache disabled; greedy outputs are checked token-identical to the dense
oracle.

A third workload reruns the shared-prefix traffic with speculative
decoding on (n-gram drafter over the same engine): reports draft accept
rate, rolled-back tokens/pages, and decode tok/s vs the spec-off engine —
with the same dense-oracle greedy-equivalence check (speculation must
change speed, never output).

A fourth workload measures tensor-parallel paged decode: the same engine
at tp=1 vs tp=2 on forced host devices (a subprocess, so this process
keeps one device), reporting decode tok/s, per-device KV bytes, and the
token-equality check — TP must change placement, never output.

A fifth workload serves an MoE model (reduced llama4-scout) through the
paged engine under both MoE dispatch modes: dropless (the serving
default — tokens can never drop, so greedy output is invariant to
prefill chunking) vs the capacity-bucketed baseline. Reports decode
tok/s for both, asserts the dropless engine's ``dropped_tokens`` stat is
exactly 0 and its greedy tokens match the dense whole-prompt oracle, and
records how many (token, expert) assignments the capacity baseline
dropped on the same traffic (the bug dropless closes).

A sixth workload runs speculative decoding on a hybrid Mamba+attention
arch (reduced jamba): every rejected draft exercises the SlotStateArena
checkpoint/restore and the full recurrent rollback-and-replay path.
Reports accept rate, recurrent rollback count, and decode tok/s vs the
same engine with spec off — with the dense-oracle greedy-equivalence
check (checkpointed recurrent state must change speed, never output).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import kvcache
from repro.models.transformer import init_params
from repro.serve.api import Request
from repro.serve.engine import DenseServeEngine, PagedServeEngine
from repro.serve.spec import SpecConfig


def _requests(n, vocab, rng, max_new):
    reqs = []
    for i in range(n):
        plen = int(rng.integers(6, 64))
        reqs.append(dict(uid=i,
                         prompt=rng.integers(0, vocab, plen).astype(np.int32),
                         max_new_tokens=max_new, adapter_id=i % 4))
    return reqs


def _family_requests(n, vocab, rng, max_new, families=4, head_len=48):
    """Shared-prefix traffic: every request's prompt starts with its
    family's common head (per-family adapter, so prefixes are shareable)."""
    heads = [rng.integers(0, vocab, head_len).astype(np.int32)
             for _ in range(families)]
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab,
                            int(rng.integers(4, 12))).astype(np.int32)
        reqs.append(dict(uid=i,
                         prompt=np.concatenate([heads[i % families], tail]),
                         max_new_tokens=max_new, adapter_id=i % families))
    return reqs


def _page_bytes(cache, num_pages):
    """Bytes one pool page costs across every paged (kp/vp) leaf."""
    total = 0
    for entry in cache["layers"]:
        for name, leaf in entry.items():
            if name in ("kp", "vp"):
                total += leaf.size * leaf.dtype.itemsize
    return total // num_pages


def _drive(make_engine, reqs, warm_passes=1):
    """Warm + measure passes over ONE engine instance (per-instance jax.jit
    caches): warm passes compile every jit signature — greedy decode is
    deterministic, so the measured pass re-hits exactly the same shapes —
    the final pass measures wall time and per-request completion latency.
    Engines with the prefix cache on need warm_passes=2: the cache is empty
    on pass 1 and saturated from pass 2 onward, so only pass 2 schedules
    (and compiles) the same chunk shapes the measured pass will re-hit."""
    eng = make_engine()

    def one_pass(uid_off):
        for r in reqs:
            eng.submit(Request(**{**r, "uid": r["uid"] + uid_off}))
        t0 = time.perf_counter()
        done_at = {}
        ticks = 0
        while (eng.queue or (eng.sched.active() if hasattr(eng, "sched")
                             else any(eng.slot_req))) and ticks < 100_000:
            eng.step()
            ticks += 1
            now = time.perf_counter() - t0
            for uid in eng.finished:
                if uid >= uid_off:
                    done_at.setdefault(uid, now)
        wall = time.perf_counter() - t0
        total_new = sum(len(r.generated) for u, r in eng.finished.items()
                        if u >= uid_off)
        lats = np.asarray([done_at[u] for u in sorted(done_at)])
        return dict(wall_s=wall, ticks=ticks, new_tokens=total_new,
                    tok_per_s=total_new / wall,
                    p50_s=float(np.percentile(lats, 50)),
                    p99_s=float(np.percentile(lats, 99)))

    for p in range(warm_passes):     # warm-up: compiles every signature
        one_pass((p + 1) * 100_000)
    return eng, one_pass((warm_passes + 1) * 100_000)  # measured: warm


_TP_PROG = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import json, time
import jax, numpy as np
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models.transformer import init_params
from repro.serve.api import ParallelConfig, Request, make_engine

spec = json.loads(os.environ['TP_BENCH_SPEC'])
cfg = reduce_config(get_config('llama3.2-1b'))
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i + 1))
            for i in range(4)]
rng = np.random.default_rng(0)
reqs = [dict(uid=i,
             prompt=rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(6, 48))).astype(np.int32),
             max_new_tokens=spec['max_new'], adapter_id=i % 4)
        for i in range(spec['n_req'])]

out = {}
for tp in spec['tps']:
    eng = make_engine(cfg, params, adapters, mode='paged',
                      max_slots=spec['max_slots'], max_len=spec['max_len'],
                      page_size=16, prefill_chunk=32,
                      parallel=ParallelConfig(tp=tp))
    for off in (0, 100_000):             # pass 1 warms every jit signature
        for r in reqs:
            eng.submit(Request(**{**r, 'uid': r['uid'] + off}))
        t0 = time.perf_counter()
        done = eng.drain()
        wall = time.perf_counter() - t0
    toks = sum(c.n_tokens for c in done.values())
    st = eng.stats()
    full_kv = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                  for l in jax.tree.leaves(eng.cache))
    out[str(tp)] = {
        'tok_per_s': toks / wall, 'wall_s': wall,
        'kv_bytes_per_device': (st.parallel.kv_bytes_per_device
                                if tp > 1 else full_kv),
        'param_bytes_per_device': st.parallel.param_bytes_per_device,
        'tokens': {str(u): list(c.tokens) for u, c in done.items()},
    }
print(json.dumps(out))
"""


def _tp_workload(smoke):
    """tp=1 vs tp=2 paged decode on forced host devices (subprocess: the
    bench process itself keeps exactly one device)."""
    spec = dict(tps=[1, 2], n_req=8 if smoke else 16,
                max_new=8 if smoke else 16, max_slots=8, max_len=256)
    env = {**os.environ,
           "PYTHONPATH": str(pathlib.Path(__file__).resolve().parents[1]
                             / "src"),
           "JAX_PLATFORMS": "cpu",
           "TP_BENCH_SPEC": json.dumps(spec)}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _TP_PROG], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    identical = out["1"]["tokens"] == out["2"]["tokens"]
    assert identical, "tp=2 greedy decode diverged from tp=1"
    return {
        "tps": spec["tps"],
        "tok_per_s": {tp: out[tp]["tok_per_s"] for tp in out},
        "kv_bytes_per_device": {tp: out[tp]["kv_bytes_per_device"]
                                for tp in out},
        "param_bytes_per_device": {tp: out[tp]["param_bytes_per_device"]
                                   for tp in out},
        "tokens_identical_across_tp": identical,
    }


def run():
    smoke = os.environ.get("BENCH_SMOKE", "0") == "1"
    cfg = reduce_config(get_config("llama3.2-1b"))
    n_req, max_new = (16, 8) if smoke else (24, 24)
    max_len, max_slots, page = (256, 16, 16) if smoke else (1024, 16, 16)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i + 1))
                for i in range(4)]
    rng = np.random.default_rng(0)
    reqs = _requests(n_req, cfg.vocab_size, rng, max_new)
    # pool sized for the mixed traffic, a fraction of the dense arena
    num_pages = max_slots * (64 + max_new + page) // page

    dense_eng, dense = _drive(
        lambda: DenseServeEngine(cfg, params, adapters=adapters,
                                 max_batch=max_slots, max_len=max_len), reqs)
    # cache off here: this workload has no prompt overlap to exploit, and
    # apples-to-apples vs dense means the PR-1 baseline configuration (the
    # prefix cache is measured on the shared-prefix workload below)
    paged_eng, paged = _drive(
        lambda: PagedServeEngine(cfg, params, adapters=adapters,
                                 max_slots=max_slots, max_len=max_len,
                                 page_size=page, num_pages=num_pages,
                                 prefill_chunk=32,
                                 enable_prefix_cache=False), reqs)

    stats = paged_eng.stats().as_dict()
    speedup = paged["tok_per_s"] / dense["tok_per_s"]
    dense_bytes = kvcache.cache_bytes(dense_eng.cache)
    paged_bytes = kvcache.cache_bytes(paged_eng.cache)
    max_sigs = (len(paged_eng.chunk_buckets) * len(paged_eng.block_buckets))
    bucketed = stats["compiled_steps"] <= max_sigs
    assert bucketed, (stats["step_signatures"], max_sigs)
    assert stats["jit_cache_size"] == stats["compiled_steps"], stats

    # ---- shared-prefix workload: prefix cache ON vs OFF (the PR-1
    # baseline), dense oracle for greedy equivalence
    srng = np.random.default_rng(1)
    sreqs = _family_requests(n_req, cfg.vocab_size, srng, max_new,
                             families=4)
    nocache_eng, nocache = _drive(
        lambda: PagedServeEngine(cfg, params, adapters=adapters,
                                 max_slots=max_slots, max_len=max_len,
                                 page_size=page, num_pages=num_pages,
                                 prefill_chunk=32,
                                 enable_prefix_cache=False), sreqs)
    shared_eng, shared = _drive(
        lambda: PagedServeEngine(cfg, params, adapters=adapters,
                                 max_slots=max_slots, max_len=max_len,
                                 page_size=page, num_pages=num_pages,
                                 prefill_chunk=32), sreqs, warm_passes=2)
    oracle_eng, _ = _drive(
        lambda: DenseServeEngine(cfg, params, adapters=adapters,
                                 max_batch=max_slots, max_len=max_len), sreqs)
    # uids are offset per pass; greedy decode is deterministic, so every
    # pass of either engine must produce the base request's tokens
    identical = all(
        shared_eng.finished[u].generated
        == oracle_eng.finished[100_000 + u % 100_000].generated
        for u in shared_eng.finished)
    assert identical, "prefix-shared paged decode diverged from dense oracle"

    # ---- spec-decode workload: same shared-prefix traffic, n-gram
    # drafter on vs off (both with the prefix cache), dense oracle check
    spec_eng, spec = _drive(
        lambda: PagedServeEngine(cfg, params, adapters=adapters,
                                 max_slots=max_slots, max_len=max_len,
                                 page_size=page, num_pages=num_pages,
                                 prefill_chunk=32,
                                 spec=SpecConfig(k=4, drafter="ngram")),
        sreqs, warm_passes=2)
    spec_identical = all(
        spec_eng.finished[u].generated
        == oracle_eng.finished[100_000 + u % 100_000].generated
        for u in spec_eng.finished)
    assert spec_identical, "spec-on greedy decode diverged from dense oracle"

    ns, ss = nocache_eng.stats().as_dict(), shared_eng.stats().as_dict()
    pb = _page_bytes(shared_eng.cache, num_pages)
    # counters accumulate over every pass (nocache ran 2, shared ran 3);
    # compare per-pass averages — the shared average still includes its
    # cold first pass, so this UNDERstates the steady-state reduction
    prefill_reduction = (ns["prefill_tokens"] / 2) / max(
        ss["prefill_tokens"] / 3, 1)
    hit_rate = ss["prefix_hit_tokens"] / max(
        ss["prefix_hit_tokens"] + ss["prefill_tokens"], 1)
    kv_peak_nocache = ns["peak_pages"] * pb
    kv_peak_shared = ss["peak_pages"] * pb

    emit("serve_dense", dense["wall_s"] * 1e6 / max(dense["ticks"], 1),
         f"tok/s={dense['tok_per_s']:.1f}_p99={dense['p99_s']*1e3:.0f}ms")
    emit("serve_paged", paged["wall_s"] * 1e6 / max(paged["ticks"], 1),
         f"tok/s={paged['tok_per_s']:.1f}_p99={paged['p99_s']*1e3:.0f}ms")
    emit("serve_speedup", 0.0,
         f"{speedup:.2f}x_decode_throughput_"
         f"{'PASS' if speedup >= 2 else 'BELOW'}_2x_target_"
         f"kv_bytes_{dense_bytes/max(paged_bytes,1):.1f}x_smaller")
    emit("serve_prefix_cache", 0.0,
         f"prefill_reduction_{prefill_reduction:.2f}x_"
         f"{'PASS' if prefill_reduction >= 2 else 'BELOW'}_2x_target_"
         f"hit_rate_{hit_rate:.2f}_"
         f"kv_peak_{kv_peak_nocache/max(kv_peak_shared,1):.2f}x_smaller")
    sp = spec_eng.stats().as_dict()
    spec_speedup = spec["tok_per_s"] / max(shared["tok_per_s"], 1e-9)
    # every verify step emits accepted_in_row + 1 tokens, so the number of
    # verify steps is decode_tokens - accepted_tokens: this ratio is the
    # step-compression factor verification buys (the memory-bound decode
    # steps saved — the win wall-clock can't see at smoke model sizes,
    # where per-tick host overhead dominates the step itself)
    tokens_per_step = (sp["decode_tokens"]
                       / max(sp["decode_tokens"] - sp["accepted_tokens"], 1))
    emit("serve_spec_decode", 0.0,
         f"accept_rate_{sp['spec_accept_rate']:.2f}_"
         f"tokens_per_decode_step_{tokens_per_step:.2f}_"
         f"wall_speedup_{spec_speedup:.2f}x_"
         f"oracle_{'PASS' if spec_identical else 'DIVERGED'}")

    # ---- MoE workload: dropless (serving default) vs capacity dispatch
    # on a reduced llama4-scout, dense oracle for greedy equivalence.
    # Prompt widths 6..48 under prefill_chunk=8 land real capacity drops
    # at the default capacity_factor (1.25): C = ceil(8*1.25/4) = 3 rows
    # for an 8-wide top-1 chunk over 4 reduced experts.
    mcfg = reduce_config(get_config("llama4-scout-17b-a16e"))
    mparams = init_params(mcfg, jax.random.PRNGKey(1))
    mrng = np.random.default_rng(2)
    m_req, m_new = (6, 6) if smoke else (12, 10)
    mreqs = [dict(uid=i,
                  prompt=mrng.integers(1, mcfg.vocab_size,
                                       int(mrng.integers(6, 48)))
                  .astype(np.int32),
                  max_new_tokens=m_new) for i in range(m_req)]
    moe_kw = dict(max_slots=8, max_len=128, page_size=8, prefill_chunk=8,
                  enable_prefix_cache=False)
    dropless_eng, dropless = _drive(
        lambda: PagedServeEngine(mcfg, mparams, **moe_kw), mreqs)
    capacity_eng, capacity = _drive(
        lambda: PagedServeEngine(mcfg, mparams, moe_dispatch="capacity",
                                 **moe_kw), mreqs)
    moracle_eng, _ = _drive(
        lambda: DenseServeEngine(mcfg, mparams, max_batch=8, max_len=128),
        mreqs)
    moe_dl = dropless_eng.stats()
    moe_cap = capacity_eng.stats()
    assert moe_dl.moe.dropped_tokens == 0, \
        "dropless serving dropped MoE tokens"
    moe_identical = all(
        dropless_eng.finished[u].generated
        == moracle_eng.finished[100_000 + u % 100_000].generated
        for u in dropless_eng.finished)
    assert moe_identical, "dropless MoE decode diverged from dense oracle"

    # ---- spec-on-hybrid workload: speculative decoding on a recurrent
    # (Mamba+attention) arch. Every rejected draft goes through the
    # SlotStateArena checkpoint/restore and the rollback-and-replay path,
    # so greedy equivalence vs the dense engine is the real acceptance bar.
    hcfg = reduce_config(get_config("jamba-1.5-large-398b"))
    hparams = init_params(hcfg, jax.random.PRNGKey(2))
    hrng = np.random.default_rng(3)
    h_req, h_new = (5, 10) if smoke else (10, 16)
    # motif-tiled prompts: repetitive enough that the n-gram drafter gets
    # real acceptances, so both accept and reject paths are measured
    hreqs = []
    for i in range(h_req):
        motif = hrng.integers(1, hcfg.vocab_size, 3).astype(np.int32)
        hreqs.append(dict(uid=i,
                          prompt=np.tile(motif, int(hrng.integers(3, 8))),
                          max_new_tokens=h_new))
    hyb_kw = dict(max_slots=4, max_len=64, page_size=8, prefill_chunk=8)
    hyb_off_eng, hyb_off = _drive(
        lambda: PagedServeEngine(hcfg, hparams, **hyb_kw), hreqs)
    hyb_on_eng, hyb_on = _drive(
        lambda: PagedServeEngine(hcfg, hparams,
                                 spec=SpecConfig(k=4, drafter="ngram"),
                                 **hyb_kw), hreqs)
    horacle_eng, _ = _drive(
        lambda: DenseServeEngine(hcfg, hparams, max_batch=4, max_len=64),
        hreqs)
    hst = hyb_on_eng.stats()
    assert hst.spec.enabled and hst.spec.disabled_reason is None
    hsd = hst.as_dict()
    hyb_identical = all(
        hyb_on_eng.finished[u].generated
        == horacle_eng.finished[100_000 + u % 100_000].generated
        for u in hyb_on_eng.finished)
    assert hyb_identical, "spec-on hybrid decode diverged from dense oracle"

    # ---- tensor-parallel workload (subprocess with 4 forced devices)
    tp = _tp_workload(smoke)
    kv1, kv2 = (tp["kv_bytes_per_device"][k] for k in ("1", "2"))
    emit("serve_tp", 0.0,
         f"tp2_tok/s={tp['tok_per_s']['2']:.1f}_"
         f"tp1_tok/s={tp['tok_per_s']['1']:.1f}_"
         f"kv/dev_{kv1/max(kv2,1):.1f}x_smaller_"
         f"tokens_{'PASS' if tp['tokens_identical_across_tp'] else 'DIVERGED'}")
    emit("serve_moe_dropless", 0.0,
         f"dropless_tok/s={dropless['tok_per_s']:.1f}_"
         f"capacity_tok/s={capacity['tok_per_s']:.1f}_"
         f"dropped_0_vs_{moe_cap.moe.dropped_tokens}_"
         f"oracle_{'PASS' if moe_identical else 'DIVERGED'}")
    emit("serve_spec_hybrid", 0.0,
         f"accept_rate_{hsd['spec_accept_rate']:.2f}_"
         f"recurrent_rollbacks_{hsd['spec_recurrent_rollbacks']}_"
         f"tok/s_on_{hyb_on['tok_per_s']:.1f}_off_{hyb_off['tok_per_s']:.1f}_"
         f"oracle_{'PASS' if hyb_identical else 'DIVERGED'}")

    payload = {
        "smoke": smoke,
        "workload": {"n_requests": n_req, "adapters": 4,
                     "prompt_lens": "6..64 mixed", "max_new": max_new,
                     "max_len": max_len, "max_slots": max_slots},
        "dense": {**dense, "kv_bytes": dense_bytes},
        "paged": {**paged, "kv_bytes": paged_bytes,
                  "page_size": page, "num_pages": num_pages,
                  "compiled_steps": stats["compiled_steps"],
                  "step_signatures": [list(s) for s in
                                      stats["step_signatures"]],
                  "max_signatures": max_sigs,
                  "preemptions": stats["preemptions"],
                  "peak_pages": stats["peak_pages"]},
        "decode_throughput_speedup": speedup,
        "meets_2x_target": bool(speedup >= 2),
        "shared_prefix": {
            "workload": {"n_requests": n_req, "families": 4,
                         "head_len": 48, "tail_lens": "4..12"},
            "nocache": {**nocache,
                        "prefill_tokens": ns["prefill_tokens"],
                        "peak_pages": ns["peak_pages"],
                        "kv_peak_bytes": kv_peak_nocache},
            "prefix_cache": {**shared,
                             "prefill_tokens": ss["prefill_tokens"],
                             "prefix_hit_tokens": ss["prefix_hit_tokens"],
                             "prefix_hits": ss["prefix_hits"],
                             "cow_forks": ss["cow_forks"],
                             "shared_pages": ss["shared_pages"],
                             "index_pages": ss.get("index_pages", 0),
                             "peak_pages": ss["peak_pages"],
                             "kv_peak_bytes": kv_peak_shared},
            "prefill_token_reduction": prefill_reduction,
            "prefix_hit_rate": hit_rate,
            "meets_2x_prefill_reduction": bool(prefill_reduction >= 2),
            "greedy_matches_dense_oracle": bool(identical),
        },
        "spec_decode": {
            "drafter": "ngram", "k": 4,
            "spec_on": {**spec,
                        "spec_steps": sp["spec_steps"],
                        "drafted_tokens": sp["drafted_tokens"],
                        "accepted_tokens": sp["accepted_tokens"],
                        "rolled_back_tokens": sp["rolled_back_tokens"],
                        "rolled_back_pages": sp["rolled_back_pages"]},
            "spec_off_tok_per_s": shared["tok_per_s"],
            "accept_rate": sp["spec_accept_rate"],
            "tokens_per_decode_step": tokens_per_step,
            "decode_throughput_speedup": spec_speedup,
            "greedy_matches_dense_oracle": bool(spec_identical),
        },
        "spec_hybrid": {
            "arch": "jamba-1.5-large-398b (reduced)",
            "drafter": "ngram", "k": 4,
            "workload": {"n_requests": h_req, "prompt_lens": "4..24",
                         "max_new": h_new, "prefill_chunk": 8},
            "spec_on": {**hyb_on,
                        "drafted_tokens": hsd["drafted_tokens"],
                        "accepted_tokens": hsd["accepted_tokens"],
                        "rolled_back_tokens": hsd["rolled_back_tokens"],
                        "recurrent_rollbacks":
                            hsd["spec_recurrent_rollbacks"]},
            "spec_off_tok_per_s": hyb_off["tok_per_s"],
            "accept_rate": hsd["spec_accept_rate"],
            "greedy_matches_dense_oracle": bool(hyb_identical),
        },
        "tensor_parallel": tp,
        "moe_dropless": {
            "arch": "llama4-scout-17b-a16e (reduced)",
            "workload": {"n_requests": m_req, "prompt_lens": "6..48",
                         "max_new": m_new, "prefill_chunk": 8},
            "dropless": {**dropless,
                         "dropped_tokens": moe_dl.moe.dropped_tokens},
            "capacity": {**capacity,
                         "dropped_tokens": moe_cap.moe.dropped_tokens},
            "dropless_over_capacity_tok_per_s":
                dropless["tok_per_s"] / max(capacity["tok_per_s"], 1e-9),
            "capacity_dropped_tokens": moe_cap.moe.dropped_tokens,
            "greedy_matches_dense_oracle": bool(moe_identical),
        },
    }
    save_json("serve_throughput", payload)
    return payload


if __name__ == "__main__":
    run()
