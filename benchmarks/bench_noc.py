"""Paper Fig. 8(a/b) + SS V.D: NoC port histograms, EDP/area/cost, and the
2D-vs-3D die-cost comparison."""
from benchmarks.common import emit, save_json
from repro.perfmodel import cost as cost_mod
from repro.perfmodel.noc import compare


def run():
    c = compare()
    for cfgname, row in c.items():
        emit(f"noc_{cfgname}", 0.0,
             f"edp={row['edp']:.3f}_area={row['noc_area']:.3f}_cost={row['cost']:.4f}")
    c3, c2, ratio = cost_mod.compare_2d_vs_3d()
    emit("cost_2d_vs_3d", 0.0, f"2d/3d={ratio:.2f}_paper=1.67")
    payload = {"noc": c, "cost_2d_vs_3d": {"3d": c3, "2d": c2, "ratio": ratio},
               "paper_targets": {"mesh_skip": {"edp": 0.88, "area": 1.16},
                                 "atleus": {"edp": 0.73, "area": 1.04},
                                 "2d_over_3d": 1.67}}
    save_json("fig8_noc", payload)
    return payload


if __name__ == "__main__":
    run()
