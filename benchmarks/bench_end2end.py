"""Paper Figs. 11 & 15: normalized fine-tuning and inference execution
time/energy vs HAIMA / 3D-TPU / GPU (Atleus = 1)."""
from benchmarks.common import PAPER_MODELS, emit, save_json
from repro.perfmodel import baselines as bl
from repro.perfmodel.atleus import TransformerDims


def run():
    payload = {}
    for mode, ft in (("finetune", True), ("inference", False)):
        payload[mode] = {}
        for name in ("roberta-base", "bert-large"):
            d = TransformerDims(name, **PAPER_MODELS[name])
            a = bl.atleus_time_energy(d, n_batches=100, fine_tuning=ft)
            row = {}
            for sysname, fn in bl.BASELINES.items():
                r = fn(d, n_batches=100, fine_tuning=ft)
                row[sysname] = {"time_x": r["time"] / a["time"],
                                "energy_x": r["energy"] / a["energy"]}
            payload[mode][name] = row
            emit(f"fig{'11' if ft else '15'}_{name}", 0.0,
                 "_".join(f"{k}={v['time_x']:.1f}x" for k, v in row.items()))
    payload["paper_claims"] = {"max_speedup_vs_sota": 56.0,
                               "max_energy_vs_sota": 64.5,
                               "tpu_vs_gpu": 2.0}
    save_json("fig11_15_end2end", payload)
    return payload


if __name__ == "__main__":
    run()
