"""Kernel microbenchmarks: pallas (interpret) vs jnp reference — parity +
wall time. (Interpret-mode timing is NOT TPU performance; the roofline
analysis covers that. This guards correctness + tracks CPU-side cost.)"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.core.quant import quantize
from repro.kernels.crossbar_matmul import ops as cb_ops, ref as cb_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.models.attention import blocked_attention, ref_attention
from repro.models.rwkv import wkv_scan

KEY = jax.random.PRNGKey(0)


def run():
    payload = {}
    # crossbar matmul
    w = jax.random.normal(KEY, (512, 256)) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 512))
    for bits in (8, 4):
        qt = quantize(w, bits)
        y, us = timed(lambda: cb_ops.crossbar_matmul(x, qt, block_m=64)
                      .block_until_ready())
        yr = cb_ref.crossbar_matmul_ref(x, qt)
        err = float(jnp.max(jnp.abs(y - yr)))
        payload[f"crossbar_int{bits}"] = {"us": us, "err": err}
        emit(f"kernel_crossbar_int{bits}", us, f"err={err:.2e}")

    # flash attention
    q = jax.random.normal(KEY, (2, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 128, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    o, us = timed(lambda: fa_ops.flash_attention(q, k, v, pos, pos,
                                                 block_q=64, block_kv=64)
                  .block_until_ready())
    oref = ref_attention(q, k, v, pos, pos)
    err = float(jnp.max(jnp.abs(o - oref)))
    payload["flash_attention"] = {"us": us, "err": err}
    emit("kernel_flash_attention", us, f"err={err:.2e}")
    _, us_jnp = timed(lambda: blocked_attention(q, k, v, pos, pos,
                                                block_kv=64)
                      .block_until_ready())
    emit("jnp_blocked_attention", us_jnp, "reference_path")

    # rwkv wkv
    r = jax.random.normal(KEY, (1, 128, 4, 32))
    kk = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 128, 4, 32))
    vv = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 128, 4, 32))
    ww = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 6),
                                          (1, 128, 4, 32)))
    u = jax.random.normal(jax.random.fold_in(KEY, 7), (4, 32)) * 0.3
    s0 = jnp.zeros((1, 4, 32, 32))
    (yk, sk), us = timed(lambda: jax.tree.map(
        lambda a: a.block_until_ready(),
        wkv_ops.rwkv6_wkv(r, kk, vv, ww, u, s0, block_t=64)))
    yref, sref = wkv_scan(r, kk, vv, ww, u, s0)
    err = float(jnp.max(jnp.abs(yk - yref)))
    payload["rwkv6_wkv"] = {"us": us, "err": err}
    emit("kernel_rwkv6_wkv", us, f"err={err:.2e}")
    save_json("kernel_micro", payload)
    return payload


if __name__ == "__main__":
    run()
