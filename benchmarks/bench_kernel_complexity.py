"""Paper Table II: measured kernel FLOP counts vs the analytic O(.) terms.
The traced tally (hetero) must match the closed forms per kernel class."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.configs import get_config, reduce_config
from repro.core import hetero
from repro.models import attention as attn_mod, layers

KEY = jax.random.PRNGKey(0)


def run():
    cfg = reduce_config(get_config("paper-gpt2-medium"), d_model=128,
                        n_heads=4, d_ff=512)
    d, ff, n, B = cfg.d_model, cfg.d_ff, 64, 2
    p_attn = attn_mod.init_attn(cfg, KEY, jnp.float32)
    p_mlp = layers.init_mlp(cfg, jax.random.fold_in(KEY, 1), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (B, n, d))
    pos = jnp.broadcast_to(jnp.arange(n)[None], (B, n))

    payload = {}
    # MHA-1..4 (static) + MHA-2/3 (dynamic)
    with hetero.tally() as t:
        jax.eval_shape(lambda p, x: attn_mod.apply_attention_block(
            cfg, p, x, pos, kind="full", impl="ref")[0], p_attn, x)
    static_expected = 2 * B * n * (d * cfg.q_dim + 2 * d * cfg.kv_dim
                                   + cfg.q_dim * d)     # MHA-1 + MHA-4
    dyn_expected = 2 * 2 * B * n * n * cfg.q_dim        # MHA-2 + MHA-3
    payload["mha"] = {"static": t[hetero.STATIC], "static_expected": static_expected,
                      "dynamic": t[hetero.DYNAMIC], "dynamic_expected": dyn_expected}
    emit("tableII_mha_static", 0.0,
         f"meas={t[hetero.STATIC]:.3g}_analytic={static_expected:.3g}")
    emit("tableII_mha_dynamic", 0.0,
         f"meas={t[hetero.DYNAMIC]:.3g}_analytic={dyn_expected:.3g}")
    assert abs(t[hetero.STATIC] - static_expected) / static_expected < 1e-6
    assert abs(t[hetero.DYNAMIC] - dyn_expected) / dyn_expected < 1e-6

    # FF-1/FF-2
    with hetero.tally() as t:
        jax.eval_shape(lambda p, x: layers.apply_mlp(cfg, p, x), p_mlp, x)
    ff_expected = 2 * B * n * (2 * d * ff + ff * d)   # gated: w1+w3 then w2
    n_mats = 3 if cfg.mlp.startswith("gated") else 2
    ff_expected = 2 * B * n * d * ff * n_mats
    payload["ff"] = {"static": t[hetero.STATIC], "expected": ff_expected}
    emit("tableII_ff", 0.0,
         f"meas={t[hetero.STATIC]:.3g}_analytic={ff_expected:.3g}")
    assert abs(t[hetero.STATIC] - ff_expected) / ff_expected < 1e-6
    save_json("tableII_complexity", payload)
    return payload


if __name__ == "__main__":
    run()
