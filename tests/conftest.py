"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests see exactly one
device; multi-device behaviour is tested via subprocesses
(test_dist_multidev.py) so device count stays isolated."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_config

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module", autouse=True)
def _bounded_compile_cache():
    """Drop compiled executables at module boundaries. A full-suite run
    accumulates thousands of XLA programs in one process (every engine
    signature, every oracle prompt length, every arch) and the CPU
    backend can segfault inside backend_compile late in the run; shapes
    rarely repeat across modules, so clearing costs almost no recompiles."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session", params=ARCH_IDS)
def arch_cfg(request):
    return reduce_config(get_config(request.param))


def assert_tree_finite(tree):
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        assert bool(jnp.all(jnp.isfinite(leaf))), jax.tree_util.keystr(path)
