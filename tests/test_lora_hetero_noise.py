"""LoRA semantics, heterogeneous-engine accounting (Eq. 5), noise model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import hetero, lora as lora_lib
from repro.core.noise import NoiseConfig, apply_weight_noise
from repro.models import transformer as tfm

KEY = jax.random.PRNGKey(5)
EC = tfm.ExecConfig(capacity_factor=16.0)


def test_lora_merge_equivalence():
    cfg = reduce_config(get_config("internlm2-20b"))
    params = tfm.init_params(cfg, KEY)
    lora = lora_lib.init_lora_params(cfg, KEY)
    lora = jax.tree.map(lambda x: x + 0.05, lora)   # nonzero B
    toks = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    l1, _, _ = tfm.forward(cfg, params, toks, lora=lora, mode="train")
    merged = lora_lib.merge_lora(cfg, params, lora)
    l2, _, _ = tfm.forward(cfg, merged, toks, mode="train")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)


def test_lora_zero_b_is_identity():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    lora = lora_lib.init_lora_params(cfg, KEY)   # b == 0
    toks = {"tokens": jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)}
    l1, _, _ = tfm.forward(cfg, params, toks, lora=lora, mode="train")
    l2, _, _ = tfm.forward(cfg, params, toks, mode="train")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_rwkv_lora_targets_translate():
    """Paper targets (wq, wv) map onto rwkv's receptance/value projections."""
    cfg = reduce_config(get_config("rwkv6-7b"))
    lora = lora_lib.init_lora_params(cfg, KEY)
    assert set(lora["layers"][0]) == {"wq", "wv"}
    assert lora_lib.count_params(lora) > 0


@pytest.mark.parametrize("arch,lo,hi", [
    ("internlm2-20b", 0.85, 1.0),      # dense: paper reports 90-94.7%
    ("mixtral-8x22b", 0.85, 1.0),      # MoE: static share grows
])
def test_eq5_static_engine_share(arch, lo, hi):
    """>=85% of matmul FLOPs land on the STATIC (ReRAM) engine even at the
    reduced scale; at paper scale the share is >90% (benchmark checks)."""
    cfg = reduce_config(get_config(arch))
    params = tfm.init_params(cfg, KEY)
    lora = lora_lib.init_lora_params(cfg, KEY)
    toks = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    rep = hetero.breakdown_of(
        lambda p, l: tfm.forward(cfg, p, toks, lora=l, mode="train",
                                 exec_cfg=EC)[0], params, lora)
    assert lo <= rep.static_share <= hi, rep.static_share


def test_eq5_ratio_scales_with_d_over_n():
    """MM_ReRAM/MM_systolic ∝ 12 d_model / n (paper Eq. 5): halving the
    sequence roughly doubles the ratio."""
    cfg = reduce_config(get_config("internlm2-20b"))
    params = tfm.init_params(cfg, KEY)

    def ratio(T):
        toks = {"tokens": jnp.zeros((2, T), jnp.int32)}
        rep = hetero.breakdown_of(
            lambda p: tfm.forward(cfg, p, toks, mode="train")[0], params)
        return rep.ratio

    r64, r128 = ratio(64), ratio(128)
    assert 1.5 < r64 / r128 < 2.5


def test_noise_clipping_and_stats():
    w = jax.random.normal(KEY, (256, 256))
    cfg = NoiseConfig(enabled=True, sigma_rel=0.05, clip=True)
    wn = apply_weight_noise(w, cfg, KEY)
    absmax = float(jnp.max(jnp.abs(w)))
    assert float(jnp.max(jnp.abs(wn))) <= absmax + 1e-6
    resid = np.asarray(wn - w).ravel()
    assert abs(resid.std() - 0.05 * absmax) / (0.05 * absmax) < 0.1
    # deterministic per key
    wn2 = apply_weight_noise(w, cfg, KEY)
    np.testing.assert_array_equal(np.asarray(wn), np.asarray(wn2))


def test_noise_disabled_is_identity():
    w = jax.random.normal(KEY, (64, 64))
    assert apply_weight_noise(w, NoiseConfig(enabled=False), None) is w


def test_noise_aware_training_runs():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    lora = lora_lib.init_lora_params(cfg, KEY)
    ec = tfm.ExecConfig(noise=NoiseConfig(enabled=True, sigma_rel=0.03))
    toks = jax.random.randint(KEY, (2, 17), 0, cfg.vocab_size)

    def loss(l, rng):
        lg, _, _ = tfm.forward(cfg, params, {"tokens": toks[:, :-1]}, lora=l,
                               mode="train", exec_cfg=ec, rng=rng)
        return tfm.lm_loss(cfg, lg, toks[:, 1:])[0]

    l1 = loss(lora, KEY)
    l2 = loss(lora, jax.random.fold_in(KEY, 1))
    assert bool(jnp.isfinite(l1)) and float(jnp.abs(l1 - l2)) > 0  # noise varies
    g = jax.grad(loss)(lora, KEY)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
