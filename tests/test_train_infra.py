"""Optimizer, grad accumulation, data determinism, checkpoint, trainer
fault-tolerance."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.pipeline import ShardInfo, SyntheticLM
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.steps import TrainHParams, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _np_adamw(p, g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    return p - lr * mh / (np.sqrt(vh) + eps), m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=0.01, grad_clip=None)
    p = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32,)),
                          jnp.float32)}
    st = adamw.init(p)
    pn = np.asarray(p["w"]).copy()
    mn = np.zeros_like(pn)
    vn = np.zeros_like(pn)
    for step in range(1, 6):
        g = {"w": jnp.asarray(np.random.default_rng(step).normal(size=(32,)),
                              jnp.float32)}
        p, st, _ = adamw.apply_updates(cfg, p, g, st)
        pn, mn, vn = _np_adamw(pn, np.asarray(g["w"]), mn, vn, step, 0.01)
        np.testing.assert_allclose(np.asarray(p["w"]), pn, rtol=1e-5,
                                   atol=1e-6)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=0.5)
    p = {"w": jnp.zeros((4,))}
    st = adamw.init(p)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw.apply_updates(cfg, p, g, st)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-5)


def test_warmup_cosine_schedule():
    s = adamw.warmup_cosine(10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# grad accumulation
# ---------------------------------------------------------------------------

def test_microbatch_accumulation_matches_full_batch():
    cfg = reduce_config(get_config("llama3.2-1b"))
    from repro.core import lora as lora_lib
    params = tfm.init_params(cfg, KEY)
    lora = lora_lib.init_lora_params(cfg, KEY)
    lora = jax.tree.map(lambda x: x + 0.03, lora)
    ec = tfm.ExecConfig()
    toks = jax.random.randint(KEY, (8, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    outs = {}
    for mb in (1, 4):
        step = make_train_step(cfg, ec, TrainHParams(
            microbatches=mb, adamw=AdamWConfig(lr=1e-2, grad_clip=None)))
        l2, _, m = step(params, lora, adamw.init(lora), batch, KEY)
        outs[mb] = (l2, float(m["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    ds = SyntheticLM(vocab_size=101, seed=4)
    b1 = ds.batch(7, 8, 32)
    b2 = ds.batch(7, 8, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_sharding_partitions_global_batch():
    ds = SyntheticLM(vocab_size=53, seed=1)
    full = ds.batch(3, 8, 16)
    s0 = ds.batch(3, 8, 16, ShardInfo(0, 2))
    s1 = ds.batch(3, 8, 16, ShardInfo(1, 2))
    np.testing.assert_array_equal(np.concatenate([s0["tokens"], s1["tokens"]]),
                                  full["tokens"])


def test_data_is_learnable_structure():
    """Bigram process: successor entropy is far below uniform."""
    ds = SyntheticLM(vocab_size=257, seed=0)
    assert ds.entropy_bound() < np.log(257) * 0.5


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "n": {"b": jnp.asarray(3)}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.latest_step(d) == 5
        back = ckpt.restore(d, jax.tree.map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        # gc kept only 2
        import pathlib
        assert len(list(pathlib.Path(d).glob("step_*"))) == 2


def test_checkpoint_restore_to_abstract_target():
    tree = {"w": jnp.ones((4, 4), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        back = ckpt.restore(d, target)
        np.testing.assert_array_equal(np.asarray(back["w"]), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# trainer fault tolerance
# ---------------------------------------------------------------------------

def test_trainer_restart_after_injected_failure():
    cfg = reduce_config(get_config("llama3.2-1b"))
    ds = SyntheticLM(cfg.vocab_size, seed=3)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(seq_len=32, global_batch=4, steps=20, ckpt_dir=d,
                           ckpt_every=5, log_every=100)
        boom = {"armed": True}

        def hook(step):
            if step == 12 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("injected failure")

        tr = Trainer(cfg, tc, ds, step_hook=hook)
        log = tr.run_with_restarts()
        assert tr.fault.restarts == 1
        assert tr.step == 20
        # steps 11..20 were re-run from the checkpoint at 10
        assert len(log) >= 20


def test_straggler_monitor_and_spare_swap():
    from repro.dist.fault import FaultCoordinator, RestartPolicy
    fc = FaultCoordinator(RestartPolicy(straggler_patience=2))
    for s in range(10):
        fc.on_step(s, 0.1)
    assert fc.on_step(10, 0.5) == "observe"       # 5x slower than EMA
    assert fc.on_step(11, 0.5) == "swap_spare"    # patience hit
    assert fc.decisions and fc.decisions[-1]["action"] == "swap_spare"


def test_elastic_resume_changes_nothing_numerically():
    """Restore on a 'different topology' (here: same host, fresh trainer) —
    training continues bit-identically thanks to stateless data indexing."""
    cfg = reduce_config(get_config("llama3.2-1b"))
    ds = SyntheticLM(cfg.vocab_size, seed=9)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(seq_len=32, global_batch=4, steps=10, ckpt_dir=d,
                           ckpt_every=5, log_every=100)
        t1 = Trainer(cfg, tc, ds)
        log1 = t1.run()
        # second trainer: restore at 5 and replay 6..10
        tc2 = TrainerConfig(seq_len=32, global_batch=4, steps=10, ckpt_dir=d,
                            ckpt_every=100, log_every=100)
        t2 = Trainer(cfg, tc2, ds)
        state = ckpt.restore(d, t2.train_state(), step=5)
        t2._load_state(state)
        log2 = t2.run()
        l1 = [r["loss"] for r in log1 if r["step"] > 5]
        l2 = [r["loss"] for r in log2]
        np.testing.assert_allclose(l1, l2, rtol=1e-6)
