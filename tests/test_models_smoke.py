"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs forward + one train step on CPU with correct shapes and
no NaNs, and prefill+decode matches the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.models.kvcache import init_cache
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)
EC = tfm.ExecConfig(capacity_factor=16.0)


def _inputs(cfg, B, T, salt=0):
    k = jax.random.fold_in(KEY, salt)
    if cfg.frontend == "tokens":
        return {"tokens": jax.random.randint(k, (B, T), 0, cfg.vocab_size)}
    return {"embeds": jax.random.normal(k, (B, T, cfg.d_model))}


def test_forward_shapes_and_finite(arch_cfg):
    cfg = arch_cfg
    params = tfm.init_params(cfg, KEY)
    B, T = 2, 32
    logits, cache, aux = tfm.forward(cfg, params, _inputs(cfg, B, T),
                                     mode="train", exec_cfg=EC)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert cache is None
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_runs_and_is_finite(arch_cfg):
    cfg = arch_cfg
    from repro.train.steps import TrainHParams, make_train_step
    params = tfm.init_params(cfg, KEY)
    lora = lora_lib.init_lora_params(cfg, KEY)
    opt = adamw.init(lora)
    step = make_train_step(cfg, EC, TrainHParams())
    B, T = 2, 16
    batch = dict(_inputs(cfg, B, T + 1))
    if "tokens" in batch:
        batch = {"tokens": batch["tokens"][:, :-1],
                 "labels": batch["tokens"][:, 1:]}
    else:
        batch["embeds"] = batch["embeds"][:, :-1]
        batch["labels"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    lora2, opt2, m = step(params, lora, opt, batch, KEY)
    assert bool(jnp.isfinite(m["loss"]))
    # some adapter actually moved (unless the arch has no LoRA targets)
    deltas = [float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(lora), jax.tree.leaves(lora2))]
    if deltas:
        assert max(deltas) > 0


def test_prefill_decode_equals_full(arch_cfg):
    cfg = arch_cfg
    params = tfm.init_params(cfg, KEY)
    B, T, Tp = 2, 24, 16
    inp = _inputs(cfg, B, T, salt=2)
    sl = (lambda s: {k: v[:, s] for k, v in inp.items()})
    full, _, _ = tfm.forward(cfg, params, inp, mode="train", exec_cfg=EC)
    cache = init_cache(cfg, B, T, kv_dtype=jnp.float32)
    pf, cache, _ = tfm.forward(cfg, params, sl(slice(0, Tp)), mode="prefill",
                               prefill_cache_len=T, cache=cache, exec_cfg=EC)
    np.testing.assert_allclose(np.asarray(pf), np.asarray(full[:, :Tp]),
                               rtol=2e-4, atol=2e-4)
    for t in range(Tp, T):
        lg, cache, _ = tfm.forward(cfg, params, sl(slice(t, t + 1)),
                                   mode="decode", cache=cache, exec_cfg=EC)
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, t]), rtol=5e-4,
                                   atol=5e-4)


def test_qlora_forward_close_to_fp(arch_cfg):
    """M8F8 crossbar-quantized base: logits deviate boundedly from fp."""
    from repro.configs.base import QuantConfig
    from repro.core import quant
    cfg = arch_cfg
    params = tfm.init_params(cfg, KEY)
    qp = quant.quantize_params(params, QuantConfig(mha_bits=8, ff_bits=8),
                               min_size=1)
    inp = _inputs(cfg, 2, 16, salt=3)
    l1, _, _ = tfm.forward(cfg, params, inp, mode="train", exec_cfg=EC)
    l2, _, _ = tfm.forward(cfg, qp, inp, mode="train", exec_cfg=EC)
    p1 = jax.nn.softmax(l1.astype(jnp.float32), -1)
    p2 = jax.nn.softmax(l2.astype(jnp.float32), -1)
    tv = float(jnp.mean(jnp.sum(jnp.abs(p1 - p2), -1)))  # total variation
    assert tv < 0.25, tv
