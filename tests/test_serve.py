"""Serving engine: continuous batching, multi-adapter, sampling, stopping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.models.kvcache import init_cache
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    ad0 = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    ad1 = jax.tree.map(lambda x: x + 0.3, ad0)
    return cfg, params, [ad0, ad1]


def _single_request_greedy(cfg, params, adapters, prompt, n, adapter_id):
    ads = lora_lib.stack_adapters(adapters)
    cache = init_cache(cfg, 1, 64, kv_dtype=jnp.float32)
    idx = jnp.asarray([adapter_id])
    lg, cache, _ = tfm.forward(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                               lora=ads, adapter_idx=idx, mode="prefill",
                               prefill_cache_len=64, cache=cache)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n - 1):
        lg, cache, _ = tfm.forward(cfg, params, {"tokens": jnp.asarray([[toks[-1]]])},
                                   lora=ads, adapter_idx=idx, mode="decode",
                                   cache=cache)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks


def test_continuous_batching_matches_single_request(setup):
    cfg, params, adapters = setup
    eng = ServeEngine(cfg, params, adapters=adapters, max_batch=3, max_len=64)
    prompts = [np.array([1, 2, 3, 4, 5]), np.array([9, 8, 7]),
               np.array([5, 5, 5, 5]), np.array([2, 4])]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6,
                           adapter_id=i % 2))
    done = eng.run_until_done()
    assert sorted(done) == [0, 1, 2, 3]
    for i, p in enumerate(prompts):
        ref = _single_request_greedy(cfg, params, adapters, p, 6, i % 2)
        assert done[i].generated == ref, (i, done[i].generated, ref)


def test_adapters_change_output(setup):
    cfg, params, adapters = setup
    p = np.array([3, 1, 4, 1, 5])
    a = _single_request_greedy(cfg, params, adapters, p, 8, 0)
    b = _single_request_greedy(cfg, params, adapters, p, 8, 1)
    assert a != b


def test_eos_stops_generation(setup):
    cfg, params, adapters = setup
    eng = ServeEngine(cfg, params, adapters=adapters, max_batch=2, max_len=64)
    ref = _single_request_greedy(cfg, params, adapters,
                                 np.array([1, 2, 3]), 10, 0)
    eos = ref[2]
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]), max_new_tokens=10,
                       adapter_id=0, eos_id=eos))
    done = eng.run_until_done()
    assert done[0].generated[-1] == eos
    assert len(done[0].generated) <= 3


def test_temperature_sampling_is_seeded(setup):
    cfg, params, adapters = setup
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, adapters=adapters, max_batch=1,
                          max_len=64, seed=42)
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]),
                           max_new_tokens=8, temperature=1.0))
        outs.append(eng.run_until_done()[0].generated)
    assert outs[0] == outs[1]
