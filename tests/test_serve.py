"""Serving engines: continuous batching, multi-adapter, sampling, stopping;
engine-vs-replay-oracle equivalence (``tests/oracle.py`` — no engine
vouches for another); bucketed compile counts."""
import jax
import numpy as np
import pytest
from oracle import replay_greedy

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.serve.api import Request
from repro.serve.engine import DenseServeEngine, PagedServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    ad0 = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    ad1 = jax.tree.map(lambda x: x + 0.3, ad0)
    return cfg, params, [ad0, ad1]


def _single_request_greedy(cfg, params, adapters, prompt, n, adapter_id):
    return replay_greedy(cfg, params, adapters, prompt, n,
                         adapter_id=adapter_id, max_len=64)


def test_continuous_batching_matches_single_request(setup):
    cfg, params, adapters = setup
    eng = DenseServeEngine(cfg, params, adapters=adapters, max_batch=3, max_len=64)
    prompts = [np.array([1, 2, 3, 4, 5]), np.array([9, 8, 7]),
               np.array([5, 5, 5, 5]), np.array([2, 4])]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=6,
                           adapter_id=i % 2))
    done = eng.run_until_done()
    assert sorted(done) == [0, 1, 2, 3]
    for i, p in enumerate(prompts):
        ref = _single_request_greedy(cfg, params, adapters, p, 6, i % 2)
        assert done[i].generated == ref, (i, done[i].generated, ref)


def test_adapters_change_output(setup):
    cfg, params, adapters = setup
    p = np.array([3, 1, 4, 1, 5])
    a = _single_request_greedy(cfg, params, adapters, p, 8, 0)
    b = _single_request_greedy(cfg, params, adapters, p, 8, 1)
    assert a != b


def test_eos_stops_generation(setup):
    cfg, params, adapters = setup
    eng = DenseServeEngine(cfg, params, adapters=adapters, max_batch=2, max_len=64)
    ref = _single_request_greedy(cfg, params, adapters,
                                 np.array([1, 2, 3]), 10, 0)
    eos = ref[2]
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]), max_new_tokens=10,
                       adapter_id=0, eos_id=eos))
    done = eng.run_until_done()
    assert done[0].generated[-1] == eos
    assert len(done[0].generated) <= 3


def test_temperature_sampling_is_seeded(setup):
    cfg, params, adapters = setup
    outs = []
    for _ in range(2):
        eng = DenseServeEngine(cfg, params, adapters=adapters, max_batch=1,
                          max_len=64, seed=42)
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]),
                           max_new_tokens=8, temperature=1.0))
        outs.append(eng.run_until_done()[0].generated)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# paged engine
# ---------------------------------------------------------------------------

MIXED_PROMPTS = [np.array([1, 2, 3, 4, 5]), np.array([9, 8, 7]),
                 np.array([5, 5, 5, 5]), np.array([2, 4]),
                 np.arange(1, 20) % 11, np.array([7] * 9),
                 np.array([3, 1, 4, 1, 5, 9, 2]), np.array([6, 6])]


def _run_engine(eng, prompts, n_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=n_new,
                           adapter_id=i % 2))
    return eng.run_until_done()


def test_paged_matches_replay_oracle_mixed_lengths_multiadapter(setup):
    """Acceptance: the paged engine must produce tokens identical to the
    engine-independent replay oracle on a mixed prompt-length,
    multi-adapter batch."""
    cfg, params, adapters = setup
    paged_eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                                 max_len=64, page_size=8, prefill_chunk=8)
    paged = _run_engine(paged_eng, MIXED_PROMPTS)
    assert sorted(paged) == list(range(len(MIXED_PROMPTS)))
    for uid, p in enumerate(MIXED_PROMPTS):
        ref = replay_greedy(cfg, params, adapters, p, 6,
                            adapter_id=uid % 2, max_len=64)
        assert paged[uid].generated == ref, uid


def test_paged_prefill_compiles_per_bucket_not_per_length(setup):
    """Acceptance: step compiles are bounded by (chunk bucket x table-width
    bucket) pairs — independent of how many distinct prompt lengths ran."""
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=4,
                           max_len=64, page_size=8, prefill_chunk=8)
    prompts = [np.arange(1, 2 + n) for n in range(1, 14)]  # 13 distinct lens
    _run_engine(eng, prompts, n_new=3)
    stats = eng.stats()
    max_sigs = len(eng.chunk_buckets) * len(eng.block_buckets)
    assert stats.compile.compiled_steps <= max_sigs
    assert stats.compile.compiled_steps < len(prompts)
    # the jit cache agrees with the engine's own signature accounting
    assert stats.compile.jit_cache_size == stats.compile.compiled_steps


def test_paged_preemption_recycles_and_preserves_outputs(setup):
    """A pool far smaller than max_slots x max_len forces preemption; the
    evicted request resumes by recompute and outputs stay identical."""
    cfg, params, adapters = setup
    prompts = [np.arange(1, 10), np.array([5, 4, 3, 2, 1, 6, 7]),
               np.array([2, 8]), np.arange(3, 15), np.array([9] * 5)]
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=32, page_size=4, num_pages=6,
                           prefill_chunk=4)
    paged = _run_engine(eng, prompts)
    for uid, p in enumerate(prompts):
        ref = replay_greedy(cfg, params, adapters, p, 6,
                            adapter_id=uid % 2, max_len=32)
        assert paged[uid].generated == ref, uid
    stats = eng.stats()
    assert stats.scheduler.preemptions >= 1        # the pool really was under pressure
    # prefix index retains finished prompts' pages; dropping its refs must
    # return every page to the free list
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0  # every page recycled at drain
    eng.sched.alloc.check_invariants()


def test_paged_temperature_sampling_is_seeded(setup):
    cfg, params, adapters = setup
    outs = []
    for _ in range(2):
        eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                               max_len=64, page_size=8, seed=42)
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]),
                           max_new_tokens=8, temperature=1.0))
        outs.append(eng.run_until_done()[0].generated)
    assert outs[0] == outs[1]


def test_paged_eos_stops_generation(setup):
    cfg, params, adapters = setup
    ref = _single_request_greedy(cfg, params, adapters,
                                 np.array([1, 2, 3]), 10, 0)
    eos = ref[2]
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                           max_len=64, page_size=8)
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]), max_new_tokens=10,
                       adapter_id=0, eos_id=eos))
    done = eng.run_until_done()
    assert done[0].generated[-1] == eos
    assert len(done[0].generated) <= 3


def test_paged_rejects_pool_infeasible_prompt_at_submit(setup):
    """A prompt that can never fit the pool fails fast at submit instead of
    head-of-line blocking feasible requests and erroring mid-flight."""
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                           max_len=32, page_size=4, num_pages=4)
    with pytest.raises(ValueError, match="more pages than the pool"):
        eng.submit(Request(uid=0, prompt=np.arange(1, 30), max_new_tokens=4))
    # feasible traffic still serves normally afterwards
    eng.submit(Request(uid=1, prompt=np.array([1, 2, 3]), max_new_tokens=3))
    done = eng.run_until_done()
    assert len(done[1].generated) == 3


def test_empty_prompt_rejected_at_submit(setup):
    cfg, params, adapters = setup
    for eng in (DenseServeEngine(cfg, params, adapters=adapters, max_batch=2,
                            max_len=32),
                PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                                 max_len=32, page_size=4)):
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(uid=0, prompt=np.array([], np.int32)))


def test_overlong_prompt_rejected_at_submit(setup):
    """Fail fast at submit — not mid-flight, where the error would discard
    other requests' finished results."""
    cfg, params, adapters = setup
    for eng in (DenseServeEngine(cfg, params, adapters=adapters, max_batch=2,
                            max_len=32),
                PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                                 max_len=32, page_size=4)):
        with pytest.raises(ValueError, match="exceeds max_len"):
            eng.submit(Request(uid=0, prompt=np.arange(1, 42)))


def test_engines_match_replay_oracle_at_max_len_boundary(setup):
    """prompt_len == max_len-1: both engines must emit the oracle's exact
    (truncated) generation, not differ by one token at the arena edge."""
    cfg, params, adapters = setup
    prompt = (np.arange(1, 32) % 13).astype(np.int32)     # 31 tokens
    assert len(prompt) == 31
    ref = replay_greedy(cfg, params, adapters, prompt, 5, adapter_id=0,
                        max_len=32)
    for make in (lambda: DenseServeEngine(cfg, params, adapters=adapters,
                                     max_batch=2, max_len=32),
                 lambda: PagedServeEngine(cfg, params, adapters=adapters,
                                          max_slots=2, max_len=32,
                                          page_size=4, prefill_chunk=8)):
        eng = make()
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
        assert eng.run_until_done()[0].generated == ref
    assert len(ref) < 5                                   # hit the arena edge


def test_paged_stream_outgrowing_pool_retires_at_capacity(setup):
    """A request that admits but whose decode growth exceeds the whole pool
    must retire gracefully at capacity — not crash the engine and not lose
    the other finished requests."""
    cfg, params, adapters = setup
    # pool = 6 pages x 4 = 24 tokens; prompt 20 + >4 new outgrows it
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                           max_len=32, page_size=4, num_pages=6)
    eng.submit(Request(uid=0, prompt=np.array([4, 2], np.int32),
                       max_new_tokens=3))
    eng.submit(Request(uid=1, prompt=np.arange(1, 21), max_new_tokens=8))
    done = eng.run_until_done()
    assert sorted(done) == [0, 1]
    assert len(done[0].generated) == 3          # small request unharmed
    assert 1 <= len(done[1].generated) < 8      # cut off at pool capacity
    assert done[1].finish_reason == "capacity"
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0      # everything recycled
