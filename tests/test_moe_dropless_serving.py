"""Dropless MoE serving: greedy tokens must be invariant to prefill
chunking, preemption, and speculative verify widths.

The paged engine slices prompts into chunks whose width is a pure
performance knob; under capacity-bucketed MoE dispatch the chunk width
changed the routing capacity bucket, so a request's OUTPUT depended on
how its prompt happened to be batched — the bug these tests pin closed.
Every paged/dense serving row now routes through ``dispatch="dropless"``,
so all of the following must produce bit-identical greedy tokens:

  * paged prefill at any chunk width,
  * paged under pool pressure (preemption + recompute-resume),
  * paged with speculative decoding (verify tails widen decode rows),
  * the dense whole-prompt oracle,
  * the moe-exact loop oracle (one token at a time — a single-token
    group can never exceed capacity, so it is drop-free by nature).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import transformer as tfm
from repro.models.kvcache import init_cache
from repro.serve.api import Request, make_engine
from repro.serve.spec import SpecConfig

KEY = jax.random.PRNGKey(7)

# chunk widths chosen so the reduced llama4-scout config (4 experts,
# capacity_factor=1.25) REALLY dropped tokens under the old capacity
# dispatch: e.g. an 8-wide top-1 chunk got C = ceil(8*1.25/4) = 3 rows
CHUNKS = (4, 8, 32)
N_NEW = 8


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama4-scout-17b-a16e"))
    assert cfg.moe is not None
    params = tfm.init_params(cfg, KEY)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 13, 29, 47)]
    return cfg, params, prompts


def _run_paged(cfg, params, prompts, **kw):
    eng = make_engine(cfg, params, mode="paged", max_len=96, **kw)
    for u, p in enumerate(prompts):
        eng.submit(Request(uid=u, prompt=p, max_new_tokens=N_NEW))
    done = eng.drain()
    return {u: done[u].tokens for u in done}, eng.stats()


def _run_dense(cfg, params, prompts):
    eng = make_engine(cfg, params, mode="dense", max_batch=len(prompts),
                      max_len=96)
    for u, p in enumerate(prompts):
        eng.submit(Request(uid=u, prompt=p, max_new_tokens=N_NEW))
    done = eng.drain()
    return {u: done[u].tokens for u in done}, eng.stats()


def _loop_oracle(cfg, params, prompt, n):
    """The moe-exact oracle: feed one token at a time (prefill included),
    so every MoE group holds a single token and capacity can never bind."""
    cache = init_cache(cfg, 1, 96, kv_dtype=jnp.float32)
    stream = [int(t) for t in prompt]
    lg = None
    for t, tok in enumerate(stream):
        lg, cache, _ = tfm.forward(
            cfg, params, {"tokens": jnp.asarray([[tok]], jnp.int32)},
            positions=jnp.asarray([[t]], jnp.int32), mode="decode",
            cache=cache)
    out = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n - 1):
        lg, cache, _ = tfm.forward(
            cfg, params,
            {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            positions=jnp.asarray([[len(stream)]], jnp.int32),
            mode="decode", cache=cache)
        stream.append(out[-1])
        out.append(int(jnp.argmax(lg[0, -1])))
    return tuple(out)


def test_greedy_invariant_to_chunk_size(setup):
    cfg, params, prompts = setup
    dense, dstats = _run_dense(cfg, params, prompts)
    assert dstats.moe.enabled and dstats.moe.dispatch == "dropless"
    assert dstats.moe.dropped_tokens == 0
    for chunk in CHUNKS:
        toks, stats = _run_paged(cfg, params, prompts, max_slots=4,
                                 prefill_chunk=chunk)
        assert toks == dense, f"chunk={chunk} diverged from dense oracle"
        assert stats.moe.dispatch == "dropless"
        assert stats.moe.dropped_tokens == 0


def test_matches_loop_oracle(setup):
    """Chunked paged serving == decoding the whole stream one token at a
    time (the inherently drop-free reference)."""
    cfg, params, prompts = setup
    ref = _loop_oracle(cfg, params, prompts[1], N_NEW)
    toks, _ = _run_paged(cfg, params, [prompts[1]], max_slots=1,
                         prefill_chunk=8)
    assert toks[0] == ref


def test_invariant_under_preemption(setup):
    """A pool too small for all requests forces preemption + resume mid
    prompt; resumed chunking differs from first-pass chunking, so this
    only holds because routing is chunk-invariant."""
    cfg, params, prompts = setup
    dense, _ = _run_dense(cfg, params, prompts)
    toks, stats = _run_paged(cfg, params, prompts, max_slots=4,
                             prefill_chunk=8, page_size=4, num_pages=16)
    assert stats.scheduler.preemptions > 0, "pool was not small enough"
    assert toks == dense
    assert stats.moe.dropped_tokens == 0


def test_invariant_with_spec_decode(setup):
    """Spec verify rows carry 1 + k real tokens — under capacity dispatch
    they'd need the old per-row moe_exact carve-out; dropless covers them
    like any other row."""
    cfg, params, prompts = setup
    dense, _ = _run_dense(cfg, params, prompts)
    for chunk in (4, 32):
        toks, stats = _run_paged(cfg, params, prompts, max_slots=4,
                                 prefill_chunk=chunk,
                                 spec=SpecConfig(k=3, drafter="ngram"))
        assert stats.spec.enabled
        assert toks == dense, f"spec+chunk={chunk} diverged"
        assert stats.moe.dropped_tokens == 0


def test_capacity_mode_really_drops(setup):
    """The bug being fixed is observable: the explicit capacity baseline
    drops (token, expert) assignments on this exact traffic, and the
    engine surfaces the count instead of raising."""
    cfg, params, prompts = setup
    _, stats = _run_paged(cfg, params, prompts, max_slots=4,
                          prefill_chunk=8, moe_dispatch="capacity")
    assert stats.moe.dispatch == "capacity"
    assert stats.moe.dropped_tokens > 0


def test_dense_engine_forces_dropless(setup):
    """The oracle overrides an exec_cfg that asks for capacity dispatch —
    whole-prompt prefill would otherwise use yet another bucket size."""
    cfg, params, prompts = setup
    eng = make_engine(cfg, params, mode="dense", max_batch=2, max_len=96,
                      exec_cfg=tfm.ExecConfig(moe_dispatch="capacity"))
    assert eng.ec.moe_dispatch == "dropless"


def test_bad_moe_dispatch_rejected(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="moe_dispatch"):
        make_engine(cfg, params, mode="paged", moe_dispatch="bogus")
