"""Speculative decoding: drafter units, spec==dense greedy equivalence
(both drafters, MoE, preemption, mid-verify rejection), paged-KV rollback
page accounting incl. shared pages, auto-disable on recurrent-state archs,
dense bucketed prefill compile counts, and the property that refcounts
drain to zero under random traffic with rollbacks."""
from types import SimpleNamespace

import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare container — CI installs the real thing
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.models.kvcache import PagedLayout
from repro.serve.api import Request, make_engine
from repro.serve.engine import DenseServeEngine, PagedServeEngine
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import PageScheduler
from repro.serve.spec import NGramDrafter, SpecConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    ad0 = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    ad1 = jax.tree.map(lambda x: x + 0.3, ad0)
    return cfg, params, [ad0, ad1]


# prompts with internal repetition so the n-gram drafter actually fires
SPEC_PROMPTS = [np.array([1, 2, 3, 1, 2, 3, 1, 2]), np.array([9, 8, 7]),
                np.array([5, 5, 5, 5, 5, 5]), np.array([2, 4]),
                np.arange(1, 20) % 5, np.array([7, 3, 7, 3, 7, 3, 7]),
                np.array([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5]),
                np.array([6, 6, 1, 6, 6, 1, 6, 6])]


def _run_engine(eng, prompts, n_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=n_new,
                           adapter_id=i % 2))
    return eng.run_until_done()


def _assert_drained(eng):
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0
    eng.sched.alloc.check_invariants()


# ---------------------------------------------------------------------------
# drafter units
# ---------------------------------------------------------------------------


def test_ngram_drafter_proposes_continuation_of_most_recent_hit():
    d = NGramDrafter(max_n=3, min_n=1)
    # suffix [5,6,7] matched at position 0 -> continuation [8,5,6,7]
    (out,) = d.propose([np.array([5, 6, 7, 8, 5, 6, 7])], [0], 3)
    assert out.tolist() == [8, 5, 6]
    # two hits for suffix [1,2]; the MOST RECENT one (followed by 8) wins
    (out,) = d.propose([np.array([1, 2, 9, 1, 2, 8, 1, 2])], [0], 4)
    assert out.tolist() == [8, 1, 2]   # truncated at end-of-stream
    # no earlier occurrence of any suffix n-gram -> empty proposal
    (out,) = d.propose([np.array([1, 2, 3, 4, 5])], [0], 4)
    assert out.size == 0
    # degenerate streams never crash
    (out,) = d.propose([np.array([7])], [0], 4)
    assert out.size == 0


# ---------------------------------------------------------------------------
# spec == dense greedy equivalence
# ---------------------------------------------------------------------------


def test_spec_ngram_matches_dense_greedy(setup):
    """Acceptance: the n-gram drafter must be token-identical to the dense
    oracle under greedy decoding — speculation changes speed, not output."""
    cfg, params, adapters = setup
    dense = _run_engine(DenseServeEngine(cfg, params, adapters=adapters,
                                         max_batch=3, max_len=64),
                        SPEC_PROMPTS, n_new=8)
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=64, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=4, drafter="ngram"))
    paged = _run_engine(eng, SPEC_PROMPTS, n_new=8)
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    stats = eng.stats()
    assert stats.spec.enabled and stats.spec.steps >= 1
    assert stats.spec.drafted_tokens >= 1       # drafting really happened
    assert stats.spec.accepted_tokens >= 1      # and some drafts survived
    _assert_drained(eng)


def test_spec_selfdraft_matches_dense_greedy(setup):
    cfg, params, adapters = setup
    dense = _run_engine(DenseServeEngine(cfg, params, adapters=adapters,
                                         max_batch=3, max_len=64),
                        SPEC_PROMPTS, n_new=8)
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=64, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=3, drafter="selfdraft",
                                           draft_bits=4, draft_ctx=32))
    paged = _run_engine(eng, SPEC_PROMPTS, n_new=8)
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    stats = eng.stats()
    assert stats.spec.drafted_tokens >= 1
    # self-draft compiles per (ctx bucket, k), not per tick
    assert stats.spec.draft_compiles <= 4
    _assert_drained(eng)


def test_spec_matches_dense_on_moe_arch():
    """Full-attention MoE: routing must survive the ragged verify chunks."""
    cfg = reduce_config(get_config("llama4-scout-17b-a16e"))
    params = tfm.init_params(cfg, KEY)
    ad = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    prompts = SPEC_PROMPTS[:4]
    dense = _run_engine(DenseServeEngine(cfg, params, adapters=[ad],
                                         max_batch=2, max_len=48),
                        prompts, n_new=5)
    eng = PagedServeEngine(cfg, params, adapters=[ad], max_slots=2,
                           max_len=48, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=3, drafter="ngram"))
    paged = _run_engine(eng, prompts, n_new=5)
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    assert eng.stats().spec.enabled
    _assert_drained(eng)


def test_spec_matches_dense_under_preemption(setup):
    """A pool far smaller than max_slots x max_len forces preemption while
    speculating; evicted requests resume by recompute, outputs identical,
    and no page leaks from rollbacks racing evictions."""
    cfg, params, adapters = setup
    dense = _run_engine(DenseServeEngine(cfg, params, adapters=adapters,
                                         max_batch=3, max_len=32),
                        SPEC_PROMPTS[:6], n_new=6)
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=32, page_size=4, num_pages=8,
                           prefill_chunk=4, spec=SpecConfig(k=4,
                                                            drafter="ngram"))
    paged = _run_engine(eng, SPEC_PROMPTS[:6], n_new=6)
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    stats = eng.stats()
    assert stats.scheduler.preemptions >= 1  # the pool really was stressed
    _assert_drained(eng)


def test_mid_verify_rejection_rolls_back(setup):
    """Some drafts MUST be rejected on this workload; every rejected token
    is accounted as rolled back (drafted == accepted + rolled_back)."""
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=64, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=4, drafter="ngram"))
    _run_engine(eng, SPEC_PROMPTS, n_new=8)
    stats = eng.stats()
    assert stats.spec.rolled_back_tokens >= 1
    assert stats.spec.rolled_back_tokens == (stats.spec.drafted_tokens
                                             - stats.spec.accepted_tokens)
    assert 0.0 < stats.spec.accept_rate < 1.0
    _assert_drained(eng)


def test_spec_composes_with_prefix_sharing(setup):
    """Shared-prefix traffic + speculation: CoW forks fire before the
    speculative writes, so rollback never corrupts a co-holder."""
    cfg, params, adapters = setup
    head = np.array([1, 2, 3, 1, 2, 3, 1, 2, 3, 4, 5, 6])
    prompts = [np.concatenate([head, np.array([t, t + 1])])
               for t in (7, 11, 13, 17)]
    dense = _run_engine(DenseServeEngine(cfg, params, adapters=adapters,
                                         max_batch=2, max_len=64),
                        prompts, n_new=6)
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                           max_len=64, page_size=4, prefill_chunk=4,
                           spec=SpecConfig(k=4, drafter="ngram"))
    paged = _run_engine(eng, prompts, n_new=6)
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    assert eng.stats().prefix_cache.hit_tokens >= 1
    _assert_drained(eng)


def test_spec_temperature_sampling_is_seeded(setup):
    cfg, params, adapters = setup
    outs = []
    for _ in range(2):
        eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                               max_len=64, page_size=8, seed=42,
                               spec=SpecConfig(k=3, drafter="ngram"))
        for i, p in enumerate(SPEC_PROMPTS[:3]):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8,
                               temperature=1.0))
        outs.append({u: r.generated
                     for u, r in eng.run_until_done().items()})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# gating
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["gemma2-9b", "jamba-1.5-large-398b"])
def test_spec_auto_disables_on_per_slot_state_archs(arch):
    """Sliding/recurrent layers keep per-slot decode state that rollback
    cannot rewind; the engine must degrade to plain decoding (and still
    match the dense oracle) rather than corrupt the ring/SSM state."""
    cfg = reduce_config(get_config(arch))
    params = tfm.init_params(cfg, KEY)
    ad = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    prompts = SPEC_PROMPTS[:3]
    dense = _run_engine(DenseServeEngine(cfg, params, adapters=[ad],
                                         max_batch=2, max_len=48),
                        prompts, n_new=5)
    eng = PagedServeEngine(cfg, params, adapters=[ad], max_slots=2,
                           max_len=48, page_size=8,
                           spec=SpecConfig(k=4, drafter="ngram"))
    stats0 = eng.stats()
    assert not stats0.spec.enabled
    assert "rollback" in stats0.spec.disabled_reason
    paged = _run_engine(eng, prompts, n_new=5)
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    assert eng.stats().spec.steps == 0       # plain decode path throughout


def test_make_engine_spec_string_and_dense_rejection(setup):
    cfg, params, adapters = setup
    eng = make_engine(cfg, params, adapters, mode="paged", max_slots=2,
                      max_len=32, page_size=8, spec="ngram")
    assert eng.stats().spec.enabled
    assert eng.spec.drafter == "ngram" and eng.spec.k == 4
    with pytest.raises(ValueError, match="paged"):
        make_engine(cfg, params, adapters, mode="dense", max_batch=2,
                    max_len=32, spec=SpecConfig())


# ---------------------------------------------------------------------------
# scheduler-level rollback accounting
# ---------------------------------------------------------------------------


def _req(tokens, adapter=0):
    return SimpleNamespace(prompt=np.asarray(tokens, np.int32),
                           adapter_id=adapter)


def test_rollback_frees_only_wholly_rejected_pages():
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    slot = sched.admit(_req(np.arange(7)), 7, tick=0)   # 7+1 tokens, 2 pages
    assert sched.ensure(slot, 12, protect=[slot])       # grow to 3 pages
    sched.lens[slot] = 12
    freed = sched.rollback(slot, 6)                     # keep 2 pages
    assert freed == 1 and sched.rolled_back_pages == 1
    assert int(sched.lens[slot]) == 6
    assert sched.tables[slot, 2] == -1 and len(sched.slots[slot].pages) == 2
    # rolling back within the kept pages frees nothing
    assert sched.rollback(slot, 5) == 0
    sched.release(slot)
    assert sched.alloc.used_pages == 0
    sched.alloc.check_invariants()


def test_rollback_spares_pages_held_by_a_co_holder():
    """A rejected-range page still referenced elsewhere (prefix index or a
    fork queued this tick) survives the rollback decref."""
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    slot = sched.admit(_req(np.arange(7)), 7, tick=0)
    tail = sched.slots[slot].pages[-1]
    sched.alloc.incref(tail)                 # simulated co-holder
    assert sched.rollback(slot, 4) == 0      # decref'd, NOT freed
    assert sched.alloc.refcount(tail) == 1
    assert sched.alloc.used_pages == 2       # kept page + surviving tail
    assert sched.alloc.decref(tail) is True  # co-holder drops it -> freed
    sched.release(slot)
    assert sched.alloc.used_pages == 0
    sched.alloc.check_invariants()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refcounts_drain_to_zero_with_rollbacks(seed):
    """Random admit/grow/rollback/finish/preempt traffic with prefix
    sharing: rollbacks interleave with CoW and eviction, and after the
    drain every page must be back on the free list."""
    rng = np.random.default_rng(seed)
    P = 4
    lay = PagedLayout(page_size=P, num_pages=16, max_slots=4)
    sched = PageScheduler(lay, max_len=24)
    idx = PrefixIndex(sched.alloc, P)
    sched.reclaim = idx.evict
    tick = 0
    for _ in range(80):
        tick += 1
        op = rng.choice(["admit", "grow", "rollback", "finish", "preempt"])
        if op == "admit" and sched.free_slot() is not None:
            plen = int(rng.integers(2, 12))
            prompt = rng.integers(0, 3, plen).astype(np.int32)
            shared = idx.lookup(0, prompt[:plen - 1])
            sched.admit(_req(prompt), plen, tick, shared=shared)
        elif op == "grow" and sched.active():
            s = int(rng.choice(sched.active()))
            new_len = int(sched.lens[s]) + int(rng.integers(1, 6))
            if new_len < 24 and sched.ensure(s, new_len, protect=[s]):
                sched.lens[s] = new_len
        elif op == "rollback" and sched.active():
            s = int(rng.choice(sched.active()))
            if int(sched.lens[s]) > 1:
                sched.rollback(s, int(rng.integers(1, sched.lens[s] + 1)))
        elif op == "finish" and sched.active():
            s = int(rng.choice(sched.active()))
            stt = sched.slots[s]
            toks = stt.req.prompt
            if int(sched.lens[s]) >= len(toks):
                idx.register(0, toks[:(len(toks) // P) * P], stt.pages, tick)
                if len(toks) % P:
                    idx.register_tail(0, toks, stt.pages[len(toks) // P],
                                      tick)
                sched.release(s)
        elif op == "preempt" and sched.active():
            sched.preempt(int(rng.choice(sched.active())))
        sched.take_forks()
        sched.drain_evicted()
    for s in sched.active():
        sched.release(s)
    idx.clear()
    assert sched.alloc.free_pages == lay.num_pages
    assert sched.alloc.shared_pages == 0
    sched.alloc.check_invariants()


# ---------------------------------------------------------------------------
# dense oracle: bucketed prefill compiles
# ---------------------------------------------------------------------------


def test_dense_prefill_compiles_per_bucket_not_per_length(setup):
    """Satellite: dense prefill pads to power-of-two buckets — three
    different prompt lengths inside one bucket share one compile."""
    cfg, params, adapters = setup
    eng = DenseServeEngine(cfg, params, adapters=adapters, max_batch=2,
                           max_len=64)
    prompts = [np.arange(1, 6), np.arange(1, 8), np.arange(1, 9),  # bucket 8
               np.arange(1, 12)]                                   # bucket 16
    dense = _run_engine(eng, prompts, n_new=4)
    assert sorted(dense) == [0, 1, 2, 3]
    stats = eng.stats()
    assert stats.compile.prefill_compiles == 2
    assert sorted(stats.compile.prefill_signatures) == [8, 16]
