"""Speculative decoding: drafter units, spec==replay-oracle greedy
equivalence (both drafters, MoE, preemption, mid-verify rejection), spec on
recurrent/hybrid architectures (SlotStateArena checkpoint + full-rewind
replay, adversarial drafters, slot recycling), paged-KV rollback page
accounting incl. shared pages, dense bucketed prefill compile counts, and
the property that refcounts drain to zero under random traffic with
rollbacks (recurrent ones included)."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from oracle import replay_greedy

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare container — CI installs the real thing
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.models.kvcache import PagedLayout, SlotStateArena, init_paged_cache
from repro.serve.api import ParallelConfig, Request, make_engine
from repro.serve.engine import DenseServeEngine, PagedServeEngine
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import PageScheduler
from repro.serve.spec import NGramDrafter, SpecConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    ad0 = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    ad1 = jax.tree.map(lambda x: x + 0.3, ad0)
    return cfg, params, [ad0, ad1]


# prompts with internal repetition so the n-gram drafter actually fires
SPEC_PROMPTS = [np.array([1, 2, 3, 1, 2, 3, 1, 2]), np.array([9, 8, 7]),
                np.array([5, 5, 5, 5, 5, 5]), np.array([2, 4]),
                np.arange(1, 20) % 5, np.array([7, 3, 7, 3, 7, 3, 7]),
                np.array([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5]),
                np.array([6, 6, 1, 6, 6, 1, 6, 6])]


def _run_engine(eng, prompts, n_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=n_new,
                           adapter_id=i % 2))
    return eng.run_until_done()


def _assert_drained(eng):
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0
    eng.sched.alloc.check_invariants()


def _oracle(cfg, params, adapters, prompts, n_new, max_len):
    """Replay every prompt through the engine-independent oracle."""
    return {i: replay_greedy(cfg, params, adapters, p, n_new,
                             adapter_id=i % 2, max_len=max_len)
            for i, p in enumerate(prompts)}


@pytest.fixture(scope="module")
def oracle64(setup):
    """Replay-oracle tokens for SPEC_PROMPTS shared by the llama tests."""
    cfg, params, adapters = setup
    return _oracle(cfg, params, adapters, SPEC_PROMPTS, 8, 64)


# ---------------------------------------------------------------------------
# drafter units
# ---------------------------------------------------------------------------


def test_ngram_drafter_proposes_continuation_of_most_recent_hit():
    d = NGramDrafter(max_n=3, min_n=1)
    # suffix [5,6,7] matched at position 0 -> continuation [8,5,6,7]
    (out,) = d.propose([np.array([5, 6, 7, 8, 5, 6, 7])], [0], 3)
    assert out.tolist() == [8, 5, 6]
    # two hits for suffix [1,2]; the MOST RECENT one (followed by 8) wins
    (out,) = d.propose([np.array([1, 2, 9, 1, 2, 8, 1, 2])], [0], 4)
    assert out.tolist() == [8, 1, 2]   # truncated at end-of-stream
    # no earlier occurrence of any suffix n-gram -> empty proposal
    (out,) = d.propose([np.array([1, 2, 3, 4, 5])], [0], 4)
    assert out.size == 0
    # degenerate streams never crash
    (out,) = d.propose([np.array([7])], [0], 4)
    assert out.size == 0


# ---------------------------------------------------------------------------
# spec == replay-oracle greedy equivalence
# ---------------------------------------------------------------------------


def test_spec_ngram_matches_replay_oracle(setup, oracle64):
    """Acceptance: the n-gram drafter must be token-identical to the
    engine-independent replay oracle under greedy decoding — speculation
    changes speed, not output."""
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=64, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=4, drafter="ngram"))
    paged = _run_engine(eng, SPEC_PROMPTS, n_new=8)
    for uid, ref in oracle64.items():
        assert paged[uid].generated == ref, uid
    stats = eng.stats()
    assert stats.spec.enabled and stats.spec.steps >= 1
    assert stats.spec.drafted_tokens >= 1       # drafting really happened
    assert stats.spec.accepted_tokens >= 1      # and some drafts survived
    _assert_drained(eng)


def test_spec_selfdraft_matches_replay_oracle(setup, oracle64):
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=64, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=3, drafter="selfdraft",
                                           draft_bits=4, draft_ctx=32))
    paged = _run_engine(eng, SPEC_PROMPTS, n_new=8)
    for uid, ref in oracle64.items():
        assert paged[uid].generated == ref, uid
    stats = eng.stats()
    assert stats.spec.drafted_tokens >= 1
    # self-draft compiles per (ctx bucket, k), not per tick
    assert stats.spec.draft_compiles <= 4
    _assert_drained(eng)


def test_spec_matches_replay_oracle_on_moe_arch():
    """Full-attention MoE: routing must survive the ragged verify chunks."""
    cfg = reduce_config(get_config("llama4-scout-17b-a16e"))
    params = tfm.init_params(cfg, KEY)
    ad = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    prompts = SPEC_PROMPTS[:4]
    eng = PagedServeEngine(cfg, params, adapters=[ad], max_slots=2,
                           max_len=48, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=3, drafter="ngram"))
    paged = _run_engine(eng, prompts, n_new=5)
    for uid, p in enumerate(prompts):
        ref = replay_greedy(cfg, params, [ad], p, 5, max_len=48)
        assert paged[uid].generated == ref, uid
    assert eng.stats().spec.enabled
    _assert_drained(eng)


def test_spec_matches_replay_oracle_under_preemption(setup):
    """A pool far smaller than max_slots x max_len forces preemption while
    speculating; evicted requests resume by recompute, outputs identical,
    and no page leaks from rollbacks racing evictions."""
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=32, page_size=4, num_pages=8,
                           prefill_chunk=4, spec=SpecConfig(k=4,
                                                            drafter="ngram"))
    paged = _run_engine(eng, SPEC_PROMPTS[:6], n_new=6)
    for uid, ref in _oracle(cfg, params, adapters, SPEC_PROMPTS[:6],
                            6, 32).items():
        assert paged[uid].generated == ref, uid
    stats = eng.stats()
    assert stats.scheduler.preemptions >= 1  # the pool really was stressed
    _assert_drained(eng)


def test_mid_verify_rejection_rolls_back(setup):
    """Some drafts MUST be rejected on this workload; every rejected token
    is accounted as rolled back (drafted == accepted + rolled_back)."""
    cfg, params, adapters = setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=64, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=4, drafter="ngram"))
    _run_engine(eng, SPEC_PROMPTS, n_new=8)
    stats = eng.stats()
    assert stats.spec.rolled_back_tokens >= 1
    assert stats.spec.rolled_back_tokens == (stats.spec.drafted_tokens
                                             - stats.spec.accepted_tokens)
    assert 0.0 < stats.spec.accept_rate < 1.0
    _assert_drained(eng)


def test_spec_composes_with_prefix_sharing(setup):
    """Shared-prefix traffic + speculation: CoW forks fire before the
    speculative writes, so rollback never corrupts a co-holder."""
    cfg, params, adapters = setup
    head = np.array([1, 2, 3, 1, 2, 3, 1, 2, 3, 4, 5, 6])
    prompts = [np.concatenate([head, np.array([t, t + 1])])
               for t in (7, 11, 13, 17)]
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                           max_len=64, page_size=4, prefill_chunk=4,
                           spec=SpecConfig(k=4, drafter="ngram"))
    paged = _run_engine(eng, prompts, n_new=6)
    for uid, ref in _oracle(cfg, params, adapters, prompts, 6, 64).items():
        assert paged[uid].generated == ref, uid
    assert eng.stats().prefix_cache.hit_tokens >= 1
    _assert_drained(eng)


def test_spec_temperature_sampling_is_seeded(setup):
    cfg, params, adapters = setup
    outs = []
    for _ in range(2):
        eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                               max_len=64, page_size=8, seed=42,
                               spec=SpecConfig(k=3, drafter="ngram"))
        for i, p in enumerate(SPEC_PROMPTS[:3]):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8,
                               temperature=1.0))
        outs.append({u: r.generated
                     for u, r in eng.run_until_done().items()})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# spec on recurrent/hybrid architectures (SlotStateArena)
# ---------------------------------------------------------------------------

RECURRENT_ARCHS = ["gemma2-9b", "jamba-1.5-large-398b", "rwkv6-7b"]


@pytest.fixture(scope="module", params=RECURRENT_ARCHS)
def rec_setup(request):
    """Per-arch params + cached replay-oracle tokens for SPEC_PROMPTS[:5]."""
    cfg = reduce_config(get_config(request.param))
    params = tfm.init_params(cfg, KEY)
    ad0 = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    ad1 = jax.tree.map(lambda x: x + 0.3, ad0)
    adapters = [ad0, ad1]
    prompts = SPEC_PROMPTS[:5]
    return cfg, params, adapters, prompts, _oracle(cfg, params, adapters,
                                                   prompts, 5, 48)


class _WrongDrafter:
    """Adversarial drafter: proposes k constant tokens every call, so most
    verify chunks reject mid-way and the recurrent rollback/replay path is
    exercised deterministically (the n-gram drafter can go quiet on
    non-repetitive model output)."""

    def __init__(self, k, tok=7):
        self.k, self.tok = k, tok

    def propose(self, streams, adapter_ids, k):
        return [np.full(min(k, self.k), self.tok, np.int32) for _ in streams]


def test_spec_enabled_and_matches_replay_oracle_on_recurrent_archs(rec_setup):
    """Acceptance: spec decoding ENABLES on sliding/Mamba/RWKV archs (no
    disabled_reason) and greedy tokens stay bit-identical to the replay
    oracle under chunked prefill + verify-chunk rollbacks."""
    cfg, params, adapters, prompts, oracle = rec_setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=48, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=4, drafter="ngram"))
    paged = _run_engine(eng, prompts, n_new=5)
    stats = eng.stats()
    assert stats.spec.enabled and stats.spec.disabled_reason is None
    assert stats.spec.steps >= 1
    for uid, ref in oracle.items():
        assert paged[uid].generated == ref, uid
    # every rejected verify chunk on a per-slot-state arch must have gone
    # through the checkpoint-restore path
    assert stats.spec.rolled_back_tokens == (stats.spec.drafted_tokens
                                             - stats.spec.accepted_tokens)
    if stats.spec.rolled_back_tokens:
        assert stats.spec.recurrent_rollbacks >= 1
    _assert_drained(eng)


def test_recurrent_rollback_and_slot_recycling_match_replay_oracle(
        rec_setup):
    """An always-wrong drafter forces a recurrent rollback on virtually
    every decode tick; outputs must still be oracle-exact. A second wave
    then reuses the recycled slots — the arena reset must have zeroed the
    restored checkpoints, so fresh requests are oracle-exact too
    (regression: stale per-slot state leaking into a recycled slot)."""
    cfg, params, adapters, prompts, oracle = rec_setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=2,
                           max_len=48, page_size=8, prefill_chunk=8,
                           spec=SpecConfig(k=3, drafter="ngram"))
    eng.drafter = _WrongDrafter(k=3)
    for wave in range(2):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=100 * wave + i, prompt=p,
                               max_new_tokens=5, adapter_id=i % 2))
        done = eng.run_until_done()
        for i in range(len(prompts)):
            assert done[100 * wave + i].generated == oracle[i], (wave, i)
    stats = eng.stats()
    assert stats.spec.recurrent_rollbacks >= 1
    assert stats.scheduler.recurrent_rollbacks == stats.spec.recurrent_rollbacks
    _assert_drained(eng)


def test_spec_recurrent_under_preemption_matches_replay_oracle(rec_setup):
    """Tiny pool: preemption interleaves with recurrent rollbacks; evicted
    requests resume by recompute and every output stays oracle-exact."""
    cfg, params, adapters, prompts, _ = rec_setup
    eng = PagedServeEngine(cfg, params, adapters=adapters, max_slots=3,
                           max_len=32, page_size=4, num_pages=6,
                           prefill_chunk=4,
                           spec=SpecConfig(k=4, drafter="ngram"))
    paged = _run_engine(eng, prompts[:4], n_new=5)
    for uid, ref in _oracle(cfg, params, adapters, prompts[:4],
                            5, 32).items():
        assert paged[uid].generated == ref, uid
    assert eng.stats().scheduler.preemptions >= 1
    _assert_drained(eng)


def test_slot_state_arena_snapshot_restore_reset():
    """Unit: restore() blends post-chunk vs checkpoint per slot; reset()
    zeroes exactly the tracked rows; pool leaves are never touched; a
    full-attention model tracks nothing (every call a no-op)."""
    cfg = reduce_config(get_config("jamba-1.5-large-398b"))
    lay = PagedLayout(page_size=4, num_pages=4, max_slots=3)
    arena = SlotStateArena(cfg)
    assert arena.tracked and any(n for n in arena.leaves)
    cache = init_paged_cache(cfg, lay, max_len=16, kv_dtype=jnp.float32)
    cache = {"layers": tuple(
        {nm: leaf + (1.0 if nm in names else 7.0)
         for nm, leaf in entry.items()}
        for entry, names in zip(cache["layers"], arena.leaves))}
    ckpt = arena.snapshot(cache)
    mutated = jax.tree.map(lambda x: x + 100.0, cache)
    keep = jnp.asarray([True, False, True])
    out = arena.restore(mutated, ckpt, keep)
    for entry, names, mut, orig in zip(out["layers"], arena.leaves,
                                       mutated["layers"], cache["layers"]):
        for nm, leaf in entry.items():
            if nm in names:   # tracked: slot 1 restored, slots 0/2 kept
                np.testing.assert_array_equal(leaf[:, 1], orig[nm][:, 1])
                np.testing.assert_array_equal(leaf[:, 0], mut[nm][:, 0])
                np.testing.assert_array_equal(leaf[:, 2], mut[nm][:, 2])
            else:             # pool leaves pass through untouched
                np.testing.assert_array_equal(leaf, mut[nm])
    out = arena.reset(out, [1])
    for entry, names, prev in zip(out["layers"], arena.leaves,
                                  mutated["layers"]):
        for nm in names:
            assert not np.asarray(entry[nm][:, 1]).any()      # zeroed
            np.testing.assert_array_equal(entry[nm][:, 0], prev[nm][:, 0])
    # full-attention-only arch: nothing tracked, everything no-ops
    dense_arena = SlotStateArena(reduce_config(get_config("llama3.2-1b")))
    assert not dense_arena.tracked
    assert dense_arena.snapshot(cache) is None
    assert dense_arena.restore(cache, None, keep) is cache
    assert dense_arena.reset(cache, [0]) is cache


needs_devices = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


@needs_devices
def test_spec_recurrent_tp2_matches_single_device():
    """Hybrid arch + spec + tp=2: the recurrent checkpoint/rollback is a
    per-slot select on replicated host inputs, so tokens and rollback
    counts must be tp-invariant."""
    cfg = reduce_config(get_config("jamba-1.5-large-398b"))
    params = tfm.init_params(cfg, KEY)
    ad = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    kw = dict(mode="paged", max_slots=3, max_len=48, page_size=8,
              prefill_chunk=8, spec=SpecConfig(k=3, drafter="ngram"))
    outs, rolls = [], []
    for par in (None, ParallelConfig(tp=2)):
        eng = make_engine(cfg, params, [ad], parallel=par, **kw)
        done = _run_engine(eng, SPEC_PROMPTS[:4], n_new=5)
        outs.append({u: r.generated for u, r in done.items()})
        rolls.append(eng.stats().spec.recurrent_rollbacks)
    assert outs[0] == outs[1]
    assert rolls[0] == rolls[1]


def test_make_engine_spec_string_and_dense_rejection(setup):
    cfg, params, adapters = setup
    eng = make_engine(cfg, params, adapters, mode="paged", max_slots=2,
                      max_len=32, page_size=8, spec="ngram")
    assert eng.stats().spec.enabled
    assert eng.spec.drafter == "ngram" and eng.spec.k == 4
    with pytest.raises(ValueError, match="paged"):
        make_engine(cfg, params, adapters, mode="dense", max_batch=2,
                    max_len=32, spec=SpecConfig())


# ---------------------------------------------------------------------------
# scheduler-level rollback accounting
# ---------------------------------------------------------------------------


def _req(tokens, adapter=0):
    return SimpleNamespace(prompt=np.asarray(tokens, np.int32),
                           adapter_id=adapter)


def test_rollback_frees_only_wholly_rejected_pages():
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    slot = sched.admit(_req(np.arange(7)), 7, tick=0)   # 7+1 tokens, 2 pages
    assert sched.ensure(slot, 12, protect=[slot])       # grow to 3 pages
    sched.lens[slot] = 12
    freed = sched.rollback(slot, 6)                     # keep 2 pages
    assert freed == 1 and sched.rolled_back_pages == 1
    assert int(sched.lens[slot]) == 6
    assert sched.tables[slot, 2] == -1 and len(sched.slots[slot].pages) == 2
    # rolling back within the kept pages frees nothing
    assert sched.rollback(slot, 5) == 0
    sched.release(slot)
    assert sched.alloc.used_pages == 0
    sched.alloc.check_invariants()


def test_rollback_spares_pages_held_by_a_co_holder():
    """A rejected-range page still referenced elsewhere (prefix index or a
    fork queued this tick) survives the rollback decref."""
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    slot = sched.admit(_req(np.arange(7)), 7, tick=0)
    tail = sched.slots[slot].pages[-1]
    sched.alloc.incref(tail)                 # simulated co-holder
    assert sched.rollback(slot, 4) == 0      # decref'd, NOT freed
    assert sched.alloc.refcount(tail) == 1
    assert sched.alloc.used_pages == 2       # kept page + surviving tail
    assert sched.alloc.decref(tail) is True  # co-holder drops it -> freed
    sched.release(slot)
    assert sched.alloc.used_pages == 0
    sched.alloc.check_invariants()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refcounts_drain_to_zero_with_rollbacks(seed):
    """Random admit/grow/rollback/finish/preempt traffic with prefix
    sharing: rollbacks interleave with CoW and eviction, and after the
    drain every page must be back on the free list.

    ``spec_rollback`` models the recurrent/hybrid settle path: a full
    rewind to the pre-chunk length issued with ``recurrent=True`` (the
    per-slot state restore itself is device-side and ephemeral — the
    scheduler must only keep the cursor/page accounting consistent and
    count the rewind)."""
    rng = np.random.default_rng(seed)
    P = 4
    lay = PagedLayout(page_size=P, num_pages=16, max_slots=4)
    sched = PageScheduler(lay, max_len=24)
    idx = PrefixIndex(sched.alloc, P)
    sched.reclaim = idx.evict
    tick = 0
    n_rec = 0
    for _ in range(80):
        tick += 1
        op = rng.choice(["admit", "grow", "rollback", "spec_rollback",
                         "finish", "preempt"])
        if op == "admit" and sched.free_slot() is not None:
            plen = int(rng.integers(2, 12))
            prompt = rng.integers(0, 3, plen).astype(np.int32)
            shared = idx.lookup(0, prompt[:plen - 1])
            sched.admit(_req(prompt), plen, tick, shared=shared)
        elif op == "grow" and sched.active():
            s = int(rng.choice(sched.active()))
            new_len = int(sched.lens[s]) + int(rng.integers(1, 6))
            if new_len < 24 and sched.ensure(s, new_len, protect=[s]):
                sched.lens[s] = new_len
        elif op == "rollback" and sched.active():
            s = int(rng.choice(sched.active()))
            if int(sched.lens[s]) > 1:
                sched.rollback(s, int(rng.integers(1, sched.lens[s] + 1)))
        elif op == "spec_rollback" and sched.active():
            # recurrent settle: grow as a verify chunk would, then rewind
            # all the way back to the pre-chunk length
            s = int(rng.choice(sched.active()))
            L = int(sched.lens[s])
            chunk = int(rng.integers(1, 5))
            if (L > 0 and L + chunk < 24
                    and sched.ensure(s, L + chunk, protect=[s])):
                sched.lens[s] = L + chunk
                sched.rollback(s, L, recurrent=True)
                n_rec += 1
        elif op == "finish" and sched.active():
            s = int(rng.choice(sched.active()))
            stt = sched.slots[s]
            toks = stt.req.prompt
            if int(sched.lens[s]) >= len(toks):
                idx.register(0, toks[:(len(toks) // P) * P], stt.pages, tick)
                if len(toks) % P:
                    idx.register_tail(0, toks, stt.pages[len(toks) // P],
                                      tick)
                sched.release(s)
        elif op == "preempt" and sched.active():
            sched.preempt(int(rng.choice(sched.active())))
        sched.take_forks()
        sched.drain_evicted()
    for s in sched.active():
        sched.release(s)
    idx.clear()
    assert sched.recurrent_rollbacks == n_rec
    assert sched.alloc.free_pages == lay.num_pages
    assert sched.alloc.shared_pages == 0
    sched.alloc.check_invariants()


# ---------------------------------------------------------------------------
# dense oracle: bucketed prefill compiles
# ---------------------------------------------------------------------------


def test_dense_prefill_compiles_per_bucket_not_per_length(setup):
    """Satellite: dense prefill pads to power-of-two buckets — three
    different prompt lengths inside one bucket share one compile."""
    cfg, params, adapters = setup
    eng = DenseServeEngine(cfg, params, adapters=adapters, max_batch=2,
                           max_len=64)
    prompts = [np.arange(1, 6), np.arange(1, 8), np.arange(1, 9),  # bucket 8
               np.arange(1, 12)]                                   # bucket 16
    dense = _run_engine(eng, prompts, n_new=4)
    assert sorted(dense) == [0, 1, 2, 3]
    stats = eng.stats()
    assert stats.compile.prefill_compiles == 2
    assert sorted(stats.compile.prefill_signatures) == [8, 16]
