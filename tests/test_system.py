"""End-to-end behaviour: the paper's full pipeline on CPU.

QLoRA fine-tune a small transformer on the synthetic corpus with
crossbar-wise quantization + noise-aware training, checkpoint it, then
evaluate with the trained adapter — loss must drop and the trained adapter
must beat a fresh-adapter baseline on next-token accuracy."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import lora as lora_lib, quant
from repro.core.noise import NoiseConfig
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig
from repro.train.steps import TrainHParams
from repro.train.trainer import Trainer, TrainerConfig


@pytest.mark.slow
def test_qlora_finetune_then_eval_end_to_end():
    cfg = reduce_config(get_config("llama3.2-1b"), d_model=128, n_heads=4,
                        d_ff=256)
    key = jax.random.PRNGKey(0)
    base = tfm.init_params(cfg, key)
    qbase = quant.quantize_params(base, QuantConfig(mha_bits=8, ff_bits=8),
                                  min_size=1)
    ds = SyntheticLM(cfg.vocab_size, seed=11)
    ec = tfm.ExecConfig(noise=NoiseConfig(enabled=True, sigma_rel=0.01))
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(
            seq_len=64, global_batch=16, steps=150, ckpt_dir=d, ckpt_every=50,
            log_every=50,
            hparams=TrainHParams(adamw=AdamWConfig(lr=5e-3)))
        tr = Trainer(cfg, tc, ds, exec_cfg=ec, params=qbase)
        log = tr.run()
    first = np.mean([r["loss"] for r in log[:10]])
    last = np.mean([r["loss"] for r in log[-10:]])
    assert last < first - 0.05, (first, last)

    # evaluate next-token accuracy: trained adapter vs fresh adapter
    batch = ds.batch(10_000, 8, 64)
    toks = jnp.asarray(batch["tokens"])
    labels = jnp.asarray(batch["labels"])

    def acc(lora):
        lg, _, _ = tfm.forward(cfg, qbase, {"tokens": toks}, lora=lora,
                               mode="train")
        return float(jnp.mean(jnp.argmax(lg, -1) == labels))

    a_trained = acc(tr.lora)
    a_fresh = acc(lora_lib.init_lora_params(cfg, jax.random.fold_in(key, 5)))
    assert a_trained >= a_fresh
