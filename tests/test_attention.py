"""Flash/banded attention vs reference, including custom-VJP gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn

KEY = jax.random.PRNGKey(3)


def _qkv(B, T, S, Hq, Hkv, D, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, salt), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(S - T, S)[None], (B, T))
    kp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return q, k, v, qp, kp


@pytest.mark.parametrize("window,softcap", [(None, None), (24, None),
                                            (None, 30.0), (24, 10.0)])
def test_flash_custom_vjp_matches_ref_grads(window, softcap):
    q, k, v, qp, kp = _qkv(2, 64, 64, 4, 2, 16)

    def loss(fn):
        def f(q, k, v):
            o = fn(q, k, v, qp, kp, window=window, softcap=softcap)
            return jnp.sum(o * (o + 0.5))
        return f

    g_ref = jax.grad(loss(attn.ref_attention), argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(loss(lambda *a, **kw: attn.blocked_attention(
        *a, block_kv=16, **kw)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_banded_equals_ref_sliding():
    B, T, Hq, Hkv, D, W = 1, 128, 4, 2, 16, 32
    q, k, v, qp, kp = _qkv(B, T, T, Hq, Hkv, D, salt=5)
    o_ref = attn.ref_attention(q, k, v, qp, kp, window=W)
    o_band = attn.banded_attention(q, k, v, qp, kp, window=W, block_q=32,
                                   block_kv=16)
    np.testing.assert_allclose(np.asarray(o_band), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_banded_grads():
    B, T, Hq, Hkv, D, W = 1, 64, 2, 2, 8, 16
    q, k, v, qp, kp = _qkv(B, T, T, Hq, Hkv, D, salt=6)

    def f_ref(q, k, v):
        return jnp.sum(attn.ref_attention(q, k, v, qp, kp, window=W) ** 2)

    def f_band(q, k, v):
        return jnp.sum(attn.banded_attention(q, k, v, qp, kp, window=W,
                                             block_q=16, block_kv=16) ** 2)

    g1 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_band, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_window_ge_seq_degenerates_to_full():
    q, k, v, qp, kp = _qkv(1, 32, 32, 2, 2, 8, salt=7)
    o_full = attn.attend(q, k, v, qp, kp, kind="full", window=None,
                         softcap=None, impl="auto", block_q=16, block_kv=16)
    o_win = attn.attend(q, k, v, qp, kp, kind="sliding", window=64,
                        softcap=None, impl="auto", block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_win),
                               rtol=1e-5, atol=1e-5)


def test_ring_cache_decode_matches_full_history():
    """Sliding decode with a ring buffer must equal attention over the last
    W tokens of the true history."""
    from repro.configs import get_config, reduce_config
    from repro.models.kvcache import init_cache
    from repro.models import transformer as tfm

    cfg = reduce_config(get_config("mixtral-8x22b"))   # sliding window 8
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 24
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    ec = tfm.ExecConfig(capacity_factor=16.0)
    full, _, _ = tfm.forward(cfg, params, {"tokens": toks}, mode="train",
                             exec_cfg=ec)
    cache = init_cache(cfg, B, T, kv_dtype=jnp.float32)
    _, cache, _ = tfm.forward(cfg, params, {"tokens": toks[:, :8]},
                              mode="prefill", prefill_cache_len=T,
                              cache=cache, exec_cfg=ec)
    errs = []
    for t in range(8, T):
        lg, cache, _ = tfm.forward(cfg, params, {"tokens": toks[:, t:t + 1]},
                                   mode="decode", cache=cache, exec_cfg=ec)
        errs.append(float(jnp.max(jnp.abs(lg[:, -1] - full[:, t]))))
    assert max(errs) < 2e-4, errs
