"""Tensor-parallel paged serving: TP=2/TP=4 must be token-identical to the
single-device paged engine and to the dense oracle, with prefix sharing,
preemption, and speculative decoding all enabled.

Multi-device runs happen in subprocesses (the main pytest process keeps one
device — see conftest). The in-process tests at the bottom only activate when
the environment already forces >= 4 devices (the CI serve-tp matrix job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and ``SERVE_TP``).
"""
import os
import pathlib
import subprocess
import sys

import jax
import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 4, timeout: int = 560) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + code)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


_COMMON = """
import jax, numpy as np
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.serve.api import Request, make_engine, ParallelConfig
from repro.serve.spec import SpecConfig

key = jax.random.PRNGKey(0)
PROMPTS = [np.array([1, 2, 3, 1, 2, 3, 1, 2]), np.array([9, 8, 7]),
           np.array([5] * 6), np.array([2, 4]), np.arange(1, 20) % 5,
           np.array([7, 3, 7, 3, 7, 3, 7])]

def run(eng, prompts, n_new=6, waves=1):
    out = {}
    for w in range(waves):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=100 * w + i, prompt=p, max_new_tokens=n_new,
                               adapter_id=i % 2))
        out.update({u: c.tokens for u, c in eng.drain().items()})
    return out
"""


def test_tp_matches_single_device_and_dense_oracle():
    """tp=2 and tp=4 greedy == tp=1 paged == dense, with prefix sharing +
    ngram spec decoding on; ParallelStats reports a genuinely sharded pool."""
    out = _run(_COMMON + """
cfg = reduce_config(get_config("llama3.2-1b"))
params = tfm.init_params(cfg, key)
ads = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
       for i in range(2)]
kw = dict(mode="paged", max_slots=4, max_len=48, page_size=8,
          prefill_chunk=8, enable_prefix_cache=True,
          spec=SpecConfig(k=3, drafter="ngram"))

oracle = run(make_engine(cfg, params, ads, mode="dense", max_len=48), PROMPTS)
base = run(make_engine(cfg, params, ads, **kw), PROMPTS, waves=2)
assert {u % 100: t for u, t in base.items() if u < 100} == oracle

full_kv = None
for tp in (2, 4):
    eng = make_engine(cfg, params, ads, parallel=ParallelConfig(tp=tp), **kw)
    toks = run(eng, PROMPTS, waves=2)
    assert toks == base, (tp, toks, base)
    st = eng.stats()
    assert st.parallel is not None and st.parallel.tp == tp
    assert len(st.parallel.devices) == tp
    if full_kv is None:
        full_kv = st.parallel.kv_bytes_per_device * tp
    assert st.parallel.kv_bytes_per_device * tp == full_kv
    assert st.prefix_cache.hit_tokens > 0  # wave 2 reuses indexed pages
    assert st.spec.enabled and st.spec.accepted_tokens > 0
    print("tp", tp, "kv/dev", st.parallel.kv_bytes_per_device)
print("OK")
""")
    assert "OK" in out


def test_tp_moe_arch_matches_single_device():
    out = _run(_COMMON + """
cfg = reduce_config(get_config("llama4-scout-17b-a16e"))
params = tfm.init_params(cfg, key)
ads = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
       for i in range(2)]
kw = dict(mode="paged", max_slots=2, max_len=48, page_size=8,
          prefill_chunk=8, spec=SpecConfig(k=3, drafter="ngram"))
base = run(make_engine(cfg, params, ads, **kw), PROMPTS[:4], 5)
tp2 = run(make_engine(cfg, params, ads, parallel=ParallelConfig(tp=2), **kw),
          PROMPTS[:4], 5)
assert tp2 == base, (tp2, base)
print("OK")
""")
    assert "OK" in out


def test_tp_recurrent_spec_matches_single_device():
    """Hybrid Mamba arch with spec decoding on: the SlotStateArena
    checkpoint/restore runs inside the sharded verify step, so tp=2 must
    stay token-identical to tp=1 and replay the same recurrent rollbacks."""
    out = _run(_COMMON + """
cfg = reduce_config(get_config("jamba-1.5-large-398b"))
params = tfm.init_params(cfg, key)
ads = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
       for i in range(2)]
kw = dict(mode="paged", max_slots=3, max_len=48, page_size=8,
          prefill_chunk=8, spec=SpecConfig(k=3, drafter="ngram"))
base_eng = make_engine(cfg, params, ads, **kw)
base = run(base_eng, PROMPTS[:4], 5)
eng = make_engine(cfg, params, ads, parallel=ParallelConfig(tp=2), **kw)
tp2 = run(eng, PROMPTS[:4], 5)
assert tp2 == base, (tp2, base)
st, st0 = eng.stats(), base_eng.stats()
assert st.spec.enabled and st.spec.disabled_reason is None
assert st.spec.recurrent_rollbacks == st0.spec.recurrent_rollbacks
print("OK recurrent_rollbacks", st.spec.recurrent_rollbacks)
""")
    assert "OK" in out


def test_tp_preemption_and_spec_rollback_match():
    """Tiny page pool forces preemption mid-decode; spec rollback trims the
    paged KV — both are host-side and must not disturb TP equivalence."""
    out = _run(_COMMON + """
cfg = reduce_config(get_config("llama3.2-1b"))
params = tfm.init_params(cfg, key)
ads = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
       for i in range(2)]
kw = dict(mode="paged", max_slots=3, max_len=32, page_size=4, num_pages=8,
          prefill_chunk=4, spec=SpecConfig(k=4, drafter="ngram"))
base = run(make_engine(cfg, params, ads, **kw), PROMPTS)
eng = make_engine(cfg, params, ads, parallel=ParallelConfig(tp=4), **kw)
tp4 = run(eng, PROMPTS)
assert tp4 == base, (tp4, base)
st = eng.stats()
assert st.scheduler.preemptions >= 1
assert st.spec.drafted_tokens > st.spec.accepted_tokens  # rollback exercised
print("OK preemptions", st.scheduler.preemptions)
""")
    assert "OK" in out


# ---------------------------------------------------------------- in-process
# These only run when the environment already provides >= 4 devices (the CI
# serve-tp matrix job). SERVE_TP picks the degree for the matrix.

_TP = int(os.environ.get("SERVE_TP", "2"))

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@needs_devices
def test_tp_inprocess_matches_single_device():
    import numpy as np
    from repro.configs import get_config, reduce_config
    from repro.core import lora as lora_lib
    from repro.models import transformer as tfm
    from repro.serve.api import ParallelConfig, Request, make_engine
    from repro.serve.spec import SpecConfig

    key = jax.random.PRNGKey(0)
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, key)
    ads = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
           for i in range(2)]
    prompts = [np.array([1, 2, 3, 1, 2, 3]), np.array([9, 8, 7]),
               np.array([5] * 6), np.array([2, 4])]

    def run(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=5,
                               adapter_id=i % 2))
        return {u: c.tokens for u, c in eng.drain().items()}

    kw = dict(mode="paged", max_slots=4, max_len=32, page_size=8,
              prefill_chunk=8, enable_prefix_cache=True,
              spec=SpecConfig(k=3, drafter="ngram"))
    base = run(make_engine(cfg, params, ads, **kw))
    eng = make_engine(cfg, params, ads, parallel=ParallelConfig(tp=_TP), **kw)
    assert run(eng) == base
    st = eng.stats()
    assert st.parallel.tp == _TP and len(st.parallel.devices) == _TP


@needs_devices
def test_tp_inprocess_parallel_stats_shrink_with_tp():
    from repro.configs import get_config, reduce_config
    from repro.models import transformer as tfm
    from repro.serve.api import ParallelConfig, make_engine

    key = jax.random.PRNGKey(0)
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, key)
    kv = {}
    for tp in (2, 4):
        eng = make_engine(cfg, params, mode="paged", max_slots=2, max_len=32,
                          page_size=8, parallel=ParallelConfig(tp=tp))
        kv[tp] = eng.stats().parallel.kv_bytes_per_device
    assert kv[2] == 2 * kv[4]
