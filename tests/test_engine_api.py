"""The redesigned engine API: typed EngineStats (dict-style access now
fully removed after its one-release deprecation window), MoEStats
reporting, ParallelConfig validation, prefix-cache persistence, and the
vectorized n-gram drafter."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.serve.api import (EngineStats, ParallelConfig, Request,
                             make_engine)
from repro.serve.spec import NGramDrafter, SpecConfig

PROMPTS = [np.array([1, 2, 3, 1, 2, 3, 1, 2]), np.array([9, 8, 7]),
           np.array([5] * 6), np.array([2, 4])]


@pytest.fixture(scope="module")
def setup(key):
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, key)
    ads = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
           for i in range(2)]
    return cfg, params, ads


def _serve(eng, n_new=5):
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=n_new,
                           adapter_id=i % 2))
    return {u: c.tokens for u, c in eng.drain().items()}


# ------------------------------------------------------------- typed stats


def test_paged_stats_typed(setup):
    cfg, params, ads = setup
    eng = make_engine(cfg, params, ads, mode="paged", max_slots=4, max_len=32,
                      page_size=8, prefill_chunk=8,
                      spec=SpecConfig(k=3, drafter="ngram"))
    _serve(eng)
    st = eng.stats()
    assert isinstance(st, EngineStats) and st.engine == "paged"
    assert st.ticks > 0 and st.decode_tokens > 0 and st.prefill_tokens > 0
    assert st.compile.compiled_steps >= 1
    assert st.scheduler is not None and st.scheduler.peak_pages > 0
    assert st.prefix_cache is not None and st.prefix_cache.enabled
    assert st.spec is not None and st.spec.enabled and st.spec.k == 3
    assert st.parallel.tp == 1 and st.parallel.devices == ()
    assert st.kv_bytes is None
    # llama3.2-1b has no MoE layers, but the section always reports the
    # dispatch mode the engine would use
    assert not st.moe.enabled
    assert st.moe.dispatch == "dropless" and st.moe.dropped_tokens == 0

    # the flat escape hatch reproduces the legacy key set
    d = st.as_dict()
    for k in ("engine", "ticks", "decode_tokens", "prefill_tokens",
              "moe_dispatch", "moe_dropped_tokens",
              "step_signatures", "compiled_steps", "jit_cache_size",
              "live_pages", "used_pages", "free_pages", "shared_pages",
              "peak_pages", "preemptions", "reclaimed_pages",
              "rolled_back_pages", "cow_forks", "prefix_hit_tokens",
              "prefix_hits", "prefix_cache_enabled", "spec_enabled",
              "spec_k", "spec_steps", "drafted_tokens", "accepted_tokens",
              "rolled_back_tokens", "spec_accept_rate", "index_nodes",
              "index_tails", "index_pages", "index_evictions"):
        assert k in d, k
    assert "tp" not in d                     # single-device: no tp section
    assert d["spec_k"] == st.spec.k
    assert d["used_pages"] == st.scheduler.used_pages


def test_dense_stats_typed(setup):
    cfg, params, ads = setup
    eng = make_engine(cfg, params, ads, mode="dense", max_len=32)
    _serve(eng)
    st = eng.stats()
    assert st.engine == "dense"
    assert st.scheduler is None and st.spec is None and st.prefix_cache is None
    assert st.kv_bytes and st.kv_bytes > 0
    assert st.compile.prefill_compiles >= 1
    d = st.as_dict()
    assert set(d) == {"engine", "ticks", "decode_tokens", "prefill_tokens",
                      "moe_dispatch", "moe_dropped_tokens",
                      "prefill_signatures", "prefill_compiles", "kv_bytes"}


def test_dict_access_removed(setup):
    """The one-release deprecation window on dict-style EngineStats access
    has closed: subscript / membership / .get are gone, not warning."""
    cfg, params, ads = setup
    eng = make_engine(cfg, params, ads, mode="paged", max_slots=2, max_len=32,
                      page_size=8)
    _serve(eng, n_new=2)
    st = eng.stats()
    with pytest.raises(TypeError):
        st["decode_tokens"]
    with pytest.raises(TypeError):
        "used_pages" in st          # noqa: B015 — probing the removed shim
    with pytest.raises(AttributeError):
        st.get("decode_tokens")
    # the typed path and as_dict stay warning-free
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _ = st.as_dict()
        _ = st.scheduler.used_pages


def test_legacy_serve_engine_name_removed():
    """The ServeEngine alias for DenseServeEngine completed its deprecation
    window — construction goes through make_engine now."""
    with pytest.raises(ImportError):
        from repro.serve.engine import ServeEngine  # noqa: F401


def test_stats_frozen(setup):
    cfg, params, ads = setup
    eng = make_engine(cfg, params, ads, mode="paged", max_slots=2, max_len=32,
                      page_size=8)
    st = eng.stats()
    with pytest.raises(dataclasses.FrozenInstanceError):
        st.ticks = 99


# ----------------------------------------------------- ParallelConfig knob


def test_parallel_config_validation(setup):
    cfg, params, ads = setup
    with pytest.raises(ValueError, match="tp must be >= 1"):
        ParallelConfig(tp=0)
    with pytest.raises(ValueError, match="mode='paged'"):
        make_engine(cfg, params, ads, mode="dense",
                    parallel=ParallelConfig(tp=2))
    with pytest.raises(ValueError, match="mode='paged'"):
        make_engine(cfg, params, ads, mode="dense", prefix_cache_path="x.npz")
    with pytest.raises(ValueError):
        make_engine(cfg, params, ads, mode="paged", max_slots=2, max_len=32,
                    page_size=8, parallel=ParallelConfig(tp=jax.device_count()
                                                         + 1))


def test_parallel_tp1_is_plain_engine(setup):
    cfg, params, ads = setup
    eng = make_engine(cfg, params, ads, mode="paged", max_slots=2, max_len=32,
                      page_size=8, parallel=ParallelConfig(tp=1))
    base = make_engine(cfg, params, ads, mode="paged", max_slots=2, max_len=32,
                       page_size=8)
    assert _serve(eng, 4) == _serve(base, 4)
    assert eng.stats().parallel.tp == 1


# ------------------------------------------------ prefix-cache persistence


def test_prefix_cache_persistence_roundtrip(setup, tmp_path):
    cfg, params, ads = setup
    path = str(tmp_path / "prefix.npz")
    kw = dict(mode="paged", max_slots=4, max_len=48, page_size=8,
              prefill_chunk=8)
    fam = np.array([4, 2, 4, 2, 4, 2, 4, 2, 9], dtype=np.int32)
    reqs = [np.concatenate([fam, np.array([t], np.int32)]) for t in range(4)]

    def serve(eng):
        for i, p in enumerate(reqs):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
        return {u: c.tokens for u, c in eng.drain().items()}

    eng1 = make_engine(cfg, params, ads, **kw)
    out1 = serve(eng1)
    saved = eng1.save_prefix_cache(path)
    assert saved > 0

    # a fresh engine restores the index and hits it on the FIRST pass
    eng2 = make_engine(cfg, params, ads, prefix_cache_path=path, **kw)
    st0 = eng2.stats()
    assert st0.prefix_cache.loaded_pages == saved
    out2 = serve(eng2)
    assert out2 == out1
    assert eng2.stats().prefix_cache.hit_tokens > 0

    # cold engine (no path): same tokens, but no first-pass hits
    eng3 = make_engine(cfg, params, ads, **kw)
    assert serve(eng3) == out1

    # geometry mismatch must be rejected loudly
    with pytest.raises(ValueError, match="page_size"):
        make_engine(cfg, params, ads, prefix_cache_path=path,
                    mode="paged", max_slots=4, max_len=48, page_size=4,
                    prefill_chunk=8)


def test_prefix_cache_path_missing_file_is_fine(setup, tmp_path):
    cfg, params, ads = setup
    eng = make_engine(cfg, params, ads, mode="paged", max_slots=2, max_len=32,
                      page_size=8,
                      prefix_cache_path=str(tmp_path / "nope.npz"))
    assert eng.stats().prefix_cache.loaded_pages == 0
    _serve(eng, 2)


# ------------------------------------------------- vectorized ngram drafter


def test_ngram_vectorized_matches_reference():
    rng = np.random.default_rng(0)
    dr = NGramDrafter(max_n=3, min_n=1)
    for _ in range(300):
        B = int(rng.integers(1, 6))
        streams = [rng.integers(0, 5, size=int(rng.integers(1, 40)))
                   .astype(np.int32) for _ in range(B)]
        k = int(rng.integers(0, 6))
        got = dr.propose(streams, [0] * B, k)
        want = dr.propose_ref(streams, [0] * B, k)
        assert len(got) == len(want) == B
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


def test_ngram_empty_and_degenerate():
    dr = NGramDrafter()
    assert [p.size for p in dr.propose([np.empty(0, np.int32)], [0], 4)] == [0]
    assert [p.size for p in dr.propose([np.array([7], np.int32)], [0], 4)] \
        == [0]
    got = dr.propose([np.array([1, 2, 1, 2, 1], np.int32)], [0], 3)
    np.testing.assert_array_equal(got[0], [2, 1])  # continuation truncated
