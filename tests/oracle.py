"""Engine-independent replay oracle for serving equivalence tests.

``replay_greedy`` runs ONE request through the raw model: a single whole-
prompt prefill, then a one-token-at-a-time decode loop over
``transformer.forward`` with a plain dense cache. No engine code is
involved — no paging, chunking, scheduling, speculation or batching — so
every serving engine (dense, paged, paged+spec, paged+tp) can be checked
against the same independent reference. This is what unblocks deleting
``DenseServeEngine``: equivalence tests no longer need one engine to
vouch for another.

Stopping rules mirror the engines exactly:
  * ``eos_id``: finish on the token that emitted it (token included);
  * ``max_new_tokens``: finish once that many tokens were generated;
  * length cap: after a decode writes cache position ``max_len - 1`` the
    request finishes — the engines always run at least one decode after
    prefill, so a prompt of ``max_len - 1`` tokens still yields two.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.models.kvcache import init_cache

# serving engines force drop-free MoE routing on every row; the oracle
# must score under the same distribution (the capacity default is the
# training dispatch)
_EC = tfm.ExecConfig(moe_dispatch="dropless")


def replay_greedy(cfg, params, adapters, prompt, max_new_tokens, *,
                  adapter_id=0, max_len=64, eos_id=None, exec_cfg=_EC):
    """Greedy tokens for one request, replayed token-at-a-time."""
    ads = lora_lib.stack_adapters(list(adapters)) if adapters else None
    idx = jnp.asarray([adapter_id]) if ads is not None else None
    prompt = np.asarray(prompt)
    cache = init_cache(cfg, 1, max_len, kv_dtype=jnp.float32)
    lg, cache, _ = tfm.forward(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, lora=ads,
        adapter_idx=idx, mode="prefill", prefill_cache_len=max_len,
        cache=cache, exec_cfg=exec_cfg)
    toks = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)                      # cache positions written

    def finished(tok):
        return ((eos_id is not None and tok == eos_id)
                or len(toks) >= max_new_tokens)

    while not finished(toks[-1]):
        lg, cache, _ = tfm.forward(
            cfg, params, {"tokens": jnp.asarray([[toks[-1]]])}, lora=ads,
            adapter_idx=idx, mode="decode", cache=cache, exec_cfg=exec_cfg)
        pos += 1
        toks.append(int(jnp.argmax(lg[0, -1])))
        if pos >= max_len - 1:             # length cap, post-decode-write
            break
    return toks
