"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.kernels.crossbar_matmul import ops as cb_ops, ref as cb_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.models.attention import ref_attention
from repro.models.rwkv import wkv_scan

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("mkn", [(32, 128, 128), (64, 256, 384),
                                 (100, 300, 130), (8, 520, 250)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_crossbar_matmul_sweep(bits, mkn, dtype):
    M, K, N = mkn
    kw, kx = jax.random.split(jax.random.fold_in(KEY, M * K * N + bits))
    w = jax.random.normal(kw, (K, N), jnp.float32) * 0.1
    x = (jax.random.normal(kx, (M, K), jnp.float32)).astype(dtype)
    qt = quantize(w, bits)
    y = cb_ops.crossbar_matmul(x, qt, block_m=32, out_dtype=jnp.float32)
    yr = cb_ref.crossbar_matmul_ref(x.astype(jnp.float32), qt,
                                    out_dtype=jnp.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=tol, atol=tol * float(jnp.max(jnp.abs(yr))))


def test_crossbar_batched_lead_dims():
    w = jax.random.normal(KEY, (256, 128)) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 5, 256))
    qt = quantize(w, 8)
    y = cb_ops.crossbar_matmul(x, qt, block_m=32)
    assert y.shape == (2, 5, 128)
    yr = cb_ref.crossbar_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("B,T,S,Hq,Hkv,D", [
    (2, 64, 64, 4, 2, 16), (1, 32, 96, 4, 4, 8), (2, 64, 64, 8, 2, 32),
    (1, 1, 64, 4, 2, 16), (1, 48, 48, 6, 3, 64),
])
@pytest.mark.parametrize("window,softcap", [(None, None), (16, None),
                                            (None, 20.0)])
def test_flash_attention_sweep(B, T, S, Hq, Hkv, D, window, softcap):
    ks = jax.random.split(jax.random.fold_in(KEY, T * S * Hq + D), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    qpos = jnp.broadcast_to(jnp.arange(S - T, S)[None], (B, T))
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_ref = ref_attention(q, k, v, qpos, kpos, window=window, softcap=softcap)
    o_ker = fa_ops.flash_attention(q, k, v, qpos, kpos, window=window,
                                   softcap=softcap, block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_invalid_slots_masked():
    """kv_pos == -1 (unwritten ring slots) must contribute nothing."""
    B, T, S, H, D = 1, 8, 32, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    qpos = jnp.broadcast_to(jnp.arange(T)[None] + 100, (B, T))
    kpos = jnp.where(jnp.arange(S) < 20, jnp.arange(S) + 90, -1)[None]
    o1 = fa_ops.flash_attention(q, k, v, qpos, kpos, block_q=8, block_kv=8)
    # corrupt the invalid region: output must not change
    k2 = k.at[:, 20:].set(999.0)
    v2 = v.at[:, 20:].set(-999.0)
    o2 = fa_ops.flash_attention(q, k2, v2, qpos, kpos, block_q=8, block_kv=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@pytest.mark.parametrize("B,T,H,N,bt", [(2, 96, 4, 16, 32), (1, 64, 2, 32, 64),
                                        (1, 50, 3, 8, 16)])
def test_rwkv6_wkv_sweep(B, T, H, N, bt):
    ks = jax.random.split(jax.random.fold_in(KEY, B * T * H * N), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    s0 = jax.random.normal(jax.random.fold_in(KEY, 9), (B, H, N, N)) * 0.1
    y_ref, s_ref = wkv_scan(r, k, v, w, u, s0)
    y_k, s_k = wkv_ops.rwkv6_wkv(r, k, v, w, u, s0, block_t=bt)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref), rtol=1e-5,
                               atol=1e-5)
