"""serve/sampling.py: the one sampling rule every engine and the spec
verifier share. Distributional check: seeded Gumbel-max categorical must
match ``jax.random.categorical`` (both ARE softmax sampling); property
check: the forbid mask never emits the forbidden token and is a no-op at
``forbid = -1``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare container — CI installs the real thing
    from _hypothesis_fallback import given, settings, st

from repro.serve.sampling import gumbel_like, sample_tokens

V = 8
LOGITS = jnp.asarray([[1.2, -0.3, 0.0, 2.1, -1.0, 0.7, 0.2, -0.6]])


def _tv(counts_a, counts_b):
    """Total-variation distance between two empirical distributions."""
    pa = counts_a / counts_a.sum()
    pb = counts_b / counts_b.sum()
    return 0.5 * np.abs(pa - pb).sum()


def _hist(draws):
    return np.bincount(np.asarray(draws).ravel(), minlength=V).astype(float)


@pytest.mark.parametrize("temp", [0.7, 1.0, 2.0])
def test_gumbel_max_matches_jax_categorical_distribution(temp):
    """N draws through sample_tokens vs jax.random.categorical on the same
    temperature-scaled logits: both empirical distributions must sit
    within sampling noise of softmax(logits/T) and of each other."""
    n = 8000
    temps = jnp.asarray([temp])
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    ours = jax.vmap(lambda k: sample_tokens(LOGITS, temps, k))(keys)
    ref = jax.random.categorical(jax.random.PRNGKey(1), LOGITS[0] / temp,
                                 shape=(n,))
    h_ours, h_ref = _hist(ours), _hist(ref)
    target = np.asarray(jax.nn.softmax(LOGITS[0] / temp)) * n
    assert _tv(h_ours, target) < 0.03
    assert _tv(h_ref, target) < 0.03
    assert _tv(h_ours, h_ref) < 0.05


def test_gumbel_like_is_gumbel_distributed():
    """Mean ~ Euler-Mascheroni, var ~ pi^2/6 — a wrong transform (e.g. a
    plain exponential) fails both."""
    g = np.asarray(gumbel_like(jax.random.PRNGKey(3), (50_000,)))
    assert abs(g.mean() - 0.5772) < 0.02
    assert abs(g.var() - np.pi**2 / 6) < 0.05


def test_temperature_zero_is_greedy_argmax():
    toks = sample_tokens(LOGITS, jnp.asarray([0.0]), jax.random.PRNGKey(7))
    assert int(toks[0]) == int(jnp.argmax(LOGITS[0]))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), forbid=st.integers(0, V - 1),
       temp=st.sampled_from([0.0, 0.5, 1.0]))
def test_forbid_mask_never_emits_forbidden_token(seed, forbid, temp):
    """Property: with one token masked per row, neither the greedy nor the
    sampled path may ever emit it — and forbid = -1 changes nothing."""
    rng = jax.random.PRNGKey(seed)
    lg = jax.random.normal(jax.random.fold_in(rng, 1), (3, V)) * 3.0
    temps = jnp.full((3,), temp)
    fb = jnp.asarray([forbid, -1, forbid])
    toks = np.asarray(sample_tokens(lg, temps, rng, forbid=fb))
    assert toks[0] != forbid and toks[2] != forbid
    # row 1 is unmasked: identical to the forbid-free call (same rng)
    plain = np.asarray(sample_tokens(lg, temps, rng))
    assert toks[1] == plain[1]
    # masking a token the row would not have picked anyway is a no-op
    if plain[0] != forbid:
        assert toks[0] == plain[0]
