"""Deterministic stand-in for the tiny slice of hypothesis the suite uses.

CI installs the real hypothesis (declared in pyproject `[test]`), which
shadows this module via the try/except in the importing tests. Environments
without it (e.g. a bare container) still run every property test, just with
a fixed seeded sample instead of adaptive shrinking.
"""
from __future__ import annotations


import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda r: fn(self._draw(r)))


class st:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def sampled_from(items):
        return _Strategy(lambda r, items=list(items): r.choice(items))

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda r: tuple(s._draw(r) for s in strats))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)


def given(**strats):
    def deco(fn):
        def run(*args, **kwargs):
            rng = random.Random(0)
            for _ in range(getattr(run, "_max_examples", 20)):
                drawn = {k: s._draw(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # deliberately no functools.wraps: pytest must see the (*args,
        # **kwargs) signature, not the strategy params (they'd be treated
        # as missing fixtures)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return deco


def settings(max_examples=20, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
