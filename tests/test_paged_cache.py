"""Paged KV arena: allocator invariants, block-table correctness vs the
dense layout, scheduler admission/preemption accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import kvcache, transformer as tfm
from repro.models.kvcache import PageAllocator, PagedLayout
from repro.serve.scheduler import (PageScheduler, bucketize, power_buckets)

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_allocator_alloc_free_recycle():
    a = PageAllocator(8)
    p1 = a.alloc(3)
    p2 = a.alloc(5)
    assert a.free_pages == 0 and a.used_pages == 8
    assert sorted(p1 + p2) == list(range(8))
    assert a.alloc(1) is None            # exhausted -> all-or-nothing None
    a.free(p1)
    assert a.free_pages == 3
    p3 = a.alloc(2)
    assert set(p3) <= set(p1)            # recycled pages come back
    a.free(p3)
    a.free(p2)
    assert a.free_pages == 8
    a.check_invariants()


def test_allocator_all_or_nothing():
    a = PageAllocator(4)
    assert a.alloc(5) is None
    assert a.free_pages == 4             # failed alloc leaks nothing
    held = a.alloc(4)
    assert a.alloc(1) is None
    a.free(held)
    a.check_invariants()


def test_allocator_double_free_detected():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(AssertionError):
        a.free([pages[0]])


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def _layout(**kw):
    base = dict(page_size=4, num_pages=8, max_slots=2)
    base.update(kw)
    return PagedLayout(**base)


def test_scheduler_admission_bounded_by_pages():
    sched = PageScheduler(_layout(), max_len=32)
    s0 = sched.admit("req0", prompt_len=13, tick=0)   # 4 pages (13+1 tokens)
    assert s0 is not None
    s1 = sched.admit("req1", prompt_len=15, tick=1)   # 4 pages
    assert s1 is not None
    assert sched.alloc.free_pages == 0
    assert sched.admit("req2", prompt_len=1, tick=2) is None  # slots full
    sched.release(s0)
    assert sched.alloc.free_pages == 4
    s2 = sched.admit("req2", prompt_len=14, tick=3)
    assert s2 == s0                                   # slot + pages recycled


def test_scheduler_growth_preempts_youngest():
    sched = PageScheduler(_layout(num_pages=5), max_len=32)
    s0 = sched.admit("old", prompt_len=7, tick=0)     # 2 pages
    s1 = sched.admit("young", prompt_len=10, tick=1)  # 3 pages, pool now dry
    sched.lens[s0] = 8
    assert sched.ensure(s0, 13, protect=[s0])         # needs 2 more pages
    assert sched.slots[s1] is None                    # young got evicted
    assert sched.drain_evicted() == ["young"]
    assert sched.preemptions == 1


def test_scheduler_block_table_maps_pages():
    lay = _layout()
    sched = PageScheduler(lay, max_len=32)
    s = sched.admit("r", prompt_len=9, tick=0)        # 3 pages for 10 tokens
    row = sched.tables[s]
    assert (row[:3] >= 0).all() and (row[3:] == -1).all()
    assert len(set(row[:3].tolist())) == 3            # distinct pages


def test_buckets():
    assert power_buckets(32) == (1, 2, 4, 8, 16, 32)
    assert bucketize(1, (1, 8, 32)) == 1
    assert bucketize(5, (1, 8, 32)) == 8
    assert bucketize(33, (1, 8, 32)) == 32             # capped


# ---------------------------------------------------------------------------
# layout equivalence: paged chunked decode == dense prefill+decode logits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-9b", "rwkv6-7b",
                                  "jamba-1.5-large-398b"])
def test_paged_chunked_forward_matches_dense(arch):
    """Feed one prompt through (a) dense whole-prompt prefill + decode and
    (b) the paged path in ragged chunks; last-token logits must agree."""
    cfg = reduce_config(get_config(arch))
    params = tfm.init_params(cfg, KEY)
    ec = tfm.ExecConfig(capacity_factor=float(cfg.moe.n_experts)
                        if cfg.moe else None)
    prompt = np.array([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5], np.int32)
    L = len(prompt)

    # dense reference
    cache = kvcache.init_cache(cfg, 1, 32, kv_dtype=jnp.float32)
    lg_ref, cache, _ = tfm.forward(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, mode="prefill",
        prefill_cache_len=32, cache=cache, exec_cfg=ec)
    lg_ref2, _, _ = tfm.forward(
        cfg, params, {"tokens": jnp.asarray([[7]])}, mode="decode",
        cache=cache, exec_cfg=ec)

    # paged: chunks of 4 padded to width 6 (ragged tails exercise masking)
    layout = PagedLayout(page_size=4, num_pages=12, max_slots=1)
    pcache = kvcache.init_paged_cache(cfg, layout, 32, kv_dtype=jnp.float32)
    table = np.full((1, layout.blocks_for(32)), -1, np.int32)
    table[0, :layout.blocks_for(L + 1)] = np.arange(layout.blocks_for(L + 1))

    def run_chunk(pcache, toks, lens, clen, width):
        t = np.zeros((1, width), np.int32)
        t[0, :len(toks)] = toks
        positions = jnp.asarray(lens + np.arange(width), jnp.int32)[None]
        paged = {"block_table": jnp.asarray(table),
                 "lens": jnp.asarray([lens], jnp.int32),
                 "chunk_lens": jnp.asarray([clen], jnp.int32),
                 "page_size": layout.page_size}
        lg, pcache, _ = tfm.forward(
            cfg, params, {"tokens": jnp.asarray(t)}, mode="decode",
            cache=pcache, positions=positions, exec_cfg=ec, paged=paged,
            chunk_lens=jnp.asarray([clen], jnp.int32))
        return lg, pcache

    lens = 0
    for start in range(0, L, 4):
        chunk = prompt[start:start + 4]
        lg_pg, pcache = run_chunk(pcache, chunk, lens, len(chunk), 6)
        lens += len(chunk)
    lg_pg2, _ = run_chunk(pcache, [7], lens, 1, 1)

    last = (L - 1) % 4
    np.testing.assert_allclose(np.asarray(lg_pg[0, last]),
                               np.asarray(lg_ref[0, -1]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(lg_pg2[0, 0]),
                               np.asarray(lg_ref2[0, -1]), atol=2e-4)
