"""Copy-on-write prefix sharing: allocator refcounts, radix index, CoW
scheduler accounting, paged==dense equivalence under sharing (divergence
mid-page, preemption of a sharer, index eviction racing a new match), and
the property that refcounts drain back to zero."""
from types import SimpleNamespace

import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare container — CI installs the real thing
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models import transformer as tfm
from repro.models.kvcache import PageAllocator, PagedLayout
from repro.serve.api import Completion, Engine, Request, make_engine
from repro.serve.engine import DenseServeEngine, PagedServeEngine
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import PageScheduler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = tfm.init_params(cfg, KEY)
    ad0 = lora_lib.init_lora_params(cfg, jax.random.fold_in(KEY, 1))
    ad1 = jax.tree.map(lambda x: x + 0.3, ad0)
    return cfg, params, [ad0, ad1]


# ---------------------------------------------------------------------------
# allocator refcounts
# ---------------------------------------------------------------------------


def test_allocator_refcount_lifecycle():
    a = PageAllocator(4)
    (p,) = a.alloc(1)
    assert a.refcount(p) == 1 and a.shared_pages == 0
    a.incref(p)
    assert a.refcount(p) == 2 and a.shared_pages == 1
    assert a.decref(p) is False          # co-held: not freed
    assert a.used_pages == 1
    assert a.decref(p) is True           # last holder: freed
    assert a.free_pages == 4
    with pytest.raises(AssertionError, match="double free"):
        a.decref(p)
    with pytest.raises(AssertionError, match="incref of free"):
        a.incref(p)
    a.check_invariants()


def test_allocator_free_reports_actually_reclaimed():
    a = PageAllocator(4)
    pages = a.alloc(3)
    a.incref(pages[0])                   # one page co-held elsewhere
    assert a.free(pages) == 2            # shared page survives its co-holder
    assert a.used_pages == 1
    assert a.decref(pages[0]) is True
    a.check_invariants()


# ---------------------------------------------------------------------------
# prefix index
# ---------------------------------------------------------------------------


def test_prefix_index_roundtrip_and_adapter_isolation():
    a = PageAllocator(16)
    idx = PrefixIndex(a, page_size=4)
    toks = list(range(10))               # 2 full pages + tail of 2
    pages = a.alloc(3)
    assert idx.register(0, toks[:8], pages[:2], tick=1) == 2
    assert idx.register_tail(0, toks, pages[2], tick=1)
    # the index holds one ref per entry on top of the owner's
    assert all(a.refcount(p) == 2 for p in pages)
    m, got = idx.lookup(0, toks)
    assert m == 10 and got == pages
    m, got = idx.lookup(0, toks[:8] + [99, 98])
    assert (m, got) == (8, pages[:2])    # tail diverges -> full pages only
    assert idx.lookup(1, toks) == (0, [])   # adapter 1: nothing shared
    # re-registration dedupes (first writer wins)
    assert idx.register(0, toks[:8], [7, 7], tick=2) == 0
    a.free(pages)                        # owner drops its refs
    assert idx.evict(need=10) == 3       # now evictable, leaf-first
    assert idx.lookup(0, toks) == (0, [])
    assert a.free_pages == 16
    a.check_invariants()


def test_prefix_index_evicts_only_unheld_leaves():
    a = PageAllocator(8)
    idx = PrefixIndex(a, page_size=4)
    toks = list(range(8))
    pages = a.alloc(2)
    idx.register(0, toks, pages, tick=1)
    a.free(pages)                        # only the index holds them now
    a.incref(pages[1])                   # ... then a slot maps the leaf page
    assert idx.evict(need=8) == 0        # leaf held -> interior unreachable
    assert idx.lookup(0, toks)[0] == 8
    a.decref(pages[1])
    assert idx.evict(need=8) == 2        # leaf then exposed parent
    a.check_invariants()


# ---------------------------------------------------------------------------
# scheduler: shared admission, CoW, preemption accounting
# ---------------------------------------------------------------------------


def _req(tokens, adapter=0):
    return SimpleNamespace(prompt=np.asarray(tokens, np.int32),
                           adapter_id=adapter)


def test_preempting_sharer_reports_only_pages_actually_freed():
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    s0 = sched.admit(_req(range(7)), 7, tick=0)       # 2 private pages
    shared_pg = sched.slots[s0].pages[0]
    s1 = sched.admit(_req(range(7)), 7, tick=1,
                     shared=(4, [shared_pg]))         # maps s0's first page
    assert sched.alloc.refcount(shared_pg) == 2
    assert int(sched.lens[s1]) == 4                   # prefill resumes there
    freed = sched.preempt(s1)
    assert freed == 1                                 # only its private page
    assert sched.reclaimed_pages == 1                 # accounting matches
    assert sched.alloc.refcount(shared_pg) == 1       # s0 unharmed
    sched.release(s0)
    assert sched.alloc.free_pages == 8
    sched.alloc.check_invariants()


def test_ensure_forks_shared_page_before_write():
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    s0 = sched.admit(_req(range(6)), 6, tick=0)
    pg = sched.slots[s0].pages[1]                     # s0's second page
    s1 = sched.admit(_req(range(6)), 6, tick=1,
                     shared=(6, list(sched.slots[s0].pages)))
    assert sched.ensure(s1, 7, protect=[s0, s1])      # writes into page col 1
    forks = sched.take_forks()
    assert len(forks) == 1 and forks[0][0] == s1 and forks[0][1] == pg
    assert sched.slots[s1].pages[1] != pg             # swapped to a fresh page
    assert sched.cow_forks == 1
    assert sched.alloc.refcount(pg) == 1              # s1 dropped its ref
    sched.release(s0)
    sched.release(s1)
    assert sched.alloc.free_pages == 8
    sched.alloc.check_invariants()


def test_release_drops_pending_forks_of_preempted_slot():
    lay = PagedLayout(page_size=4, num_pages=8, max_slots=2)
    sched = PageScheduler(lay, max_len=32)
    s0 = sched.admit(_req(range(6)), 6, tick=0)
    s1 = sched.admit(_req(range(6)), 6, tick=1,
                     shared=(6, list(sched.slots[s0].pages)))
    assert sched.ensure(s1, 7, protect=[s0, s1])
    sched.preempt(s1)                    # fork queued, then slot evicted
    assert sched.take_forks() == []      # stale copy must not execute
    sched.release(s0)
    sched.alloc.check_invariants()


# ---------------------------------------------------------------------------
# engine equivalence under sharing
# ---------------------------------------------------------------------------


def _family(rng, vocab, head_len, tails, head=None):
    head = (rng.integers(0, vocab, head_len).astype(np.int32)
            if head is None else head)
    return head, [np.concatenate([
        head, rng.integers(0, vocab, t).astype(np.int32)]) for t in tails]


def _drive_pair(cfg, params, adapters, prompts, dense_kw, paged_kw, n_new=6,
                adapter_of=lambda i: 0):
    reqs = [dict(uid=i, prompt=p, max_new_tokens=n_new,
                 adapter_id=adapter_of(i)) for i, p in enumerate(prompts)]
    dense = DenseServeEngine(cfg, params, adapters=adapters, **dense_kw)
    paged = PagedServeEngine(cfg, params, adapters=adapters, **paged_kw)
    for eng in (dense, paged):
        for r in reqs:
            eng.submit(Request(**r))
    ddone, pdone = dense.run_until_done(), paged.run_until_done()
    assert sorted(pdone) == sorted(ddone)
    for uid in ddone:
        assert pdone[uid].generated == ddone[uid].generated, uid
    return paged


def test_shared_prefix_diverging_mid_page_matches_dense(setup):
    """Six requests share a 21-token head (page_size 8: two full pages plus
    five tokens INTO the third). The first request's prompt IS the head, so
    its finish donates the partial third page; later sharers map it, fork it
    copy-on-write at their divergent token, and still match the oracle."""
    cfg, params, adapters = setup
    rng = np.random.default_rng(3)
    _, prompts = _family(rng, cfg.vocab_size, 21, [0, 3, 5, 7, 4, 6])
    eng = _drive_pair(cfg, params, adapters, prompts,
                      dict(max_batch=3, max_len=64),
                      dict(max_slots=3, max_len=64, page_size=8,
                           num_pages=48, prefill_chunk=8))
    stats = eng.stats()
    assert stats.prefix_cache.hit_tokens > 0
    assert stats.prefix_cache.hits >= 4
    assert stats.scheduler.cow_forks >= 1       # the partial tail page was forked
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0
    eng.sched.alloc.check_invariants()


def test_preempted_sharer_resumes_and_matches_dense(setup):
    """Pool pressure preempts a request that mapped shared pages; it must
    resume by recompute (re-matching whatever is still indexed) and finish
    with oracle-identical tokens."""
    cfg, params, adapters = setup
    rng = np.random.default_rng(5)
    _, prompts = _family(rng, cfg.vocab_size, 6, [2, 4, 6, 3, 5])
    eng = _drive_pair(cfg, params, adapters, prompts,
                      dict(max_batch=3, max_len=32),
                      dict(max_slots=3, max_len=32, page_size=4,
                           num_pages=8, prefill_chunk=4))
    stats = eng.stats()
    assert stats.scheduler.preemptions >= 1
    assert stats.prefix_cache.hit_tokens > 0
    assert stats.scheduler.reclaimed_pages <= stats.scheduler.preemptions * \
        eng.sched.max_blocks             # never overreports freed pages
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0
    eng.sched.alloc.check_invariants()


def test_index_eviction_racing_new_match_matches_dense(setup):
    """A finished family's index pages get reclaimed by unrelated traffic
    while a late request matching that family is still queued — whichever
    pages survive, outputs must stay oracle-identical."""
    cfg, params, adapters = setup
    rng = np.random.default_rng(9)
    head1, fam1 = _family(rng, cfg.vocab_size, 12, [2])
    _, fam2 = _family(rng, cfg.vocab_size, 14, [3, 4])   # distinct head
    _, late = _family(rng, cfg.vocab_size, 12, [2, 5], head=head1)
    prompts = fam1 + fam2 + late[1:]     # late[0] == fam1[0]'s twin family
    eng = _drive_pair(cfg, params, adapters, prompts,
                      dict(max_batch=2, max_len=32),
                      dict(max_slots=2, max_len=32, page_size=4,
                           num_pages=10, prefill_chunk=4), n_new=4)
    stats = eng.stats()
    assert stats.prefix_cache.index_evictions >= 1     # the race actually happened
    assert stats.prefix_cache.hit_tokens > 0
    eng.release_prefix_cache()
    assert eng.sched.alloc.used_pages == 0
    eng.sched.alloc.check_invariants()


def test_prefix_sharing_isolated_across_adapters(setup):
    """Same prompt under different LoRA adapters produces different K/V —
    the index must never share across adapter ids (outputs stay oracle-
    identical AND adapter 1's first request gets zero hits)."""
    cfg, params, adapters = setup
    rng = np.random.default_rng(11)
    _, prompts = _family(rng, cfg.vocab_size, 12, [3, 3, 4, 4])
    eng = _drive_pair(cfg, params, adapters, prompts,
                      dict(max_batch=2, max_len=64),
                      dict(max_slots=2, max_len=64, page_size=4,
                           num_pages=32, prefill_chunk=8),
                      adapter_of=lambda i: i % 2)
    # 4 requests, 2 per adapter -> at most one hit per adapter's family,
    # and full-prompt prefill ran at least once per adapter
    assert eng.stats().prefix_cache.hits == 2
    eng.release_prefix_cache()
    eng.sched.alloc.check_invariants()


def test_prefix_cache_disabled_for_non_full_attention():
    """Sliding-window rings (and recurrent state) are per-slot and cannot
    be shared — the engine must auto-disable the prefix cache."""
    cfg = reduce_config(get_config("gemma2-9b"))
    params = tfm.init_params(cfg, KEY)
    eng = PagedServeEngine(cfg, params, max_slots=2, max_len=32, page_size=4)
    assert eng.prefix is None
    assert eng.release_prefix_cache() == 0
    assert eng.stats().prefix_cache.enabled is False


# ---------------------------------------------------------------------------
# unified API surface
# ---------------------------------------------------------------------------


def test_make_engine_modes_and_completions(setup):
    cfg, params, adapters = setup
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    outs = {}
    for mode, kw in (("paged", dict(max_slots=2, page_size=8)),
                     ("dense", dict(max_batch=2))):
        eng = make_engine(cfg, params, adapters, mode=mode, max_len=64, **kw)
        assert isinstance(eng, Engine)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
        done = eng.drain()
        c = done[0]
        assert isinstance(c, Completion)
        assert c.prompt == tuple(prompt) and c.n_tokens == 4
        assert c.finish_reason == "length"
        outs[mode] = c.tokens
    assert outs["paged"] == outs["dense"]
    with pytest.raises(ValueError, match="unknown engine mode"):
        make_engine(cfg, params, mode="sparse")


def test_legacy_serve_engine_removed():
    """The deprecated ServeEngine alias completed its one-release window
    and is gone — make_engine is the only construction point."""
    with pytest.raises(ImportError):
        from repro.serve.engine import ServeEngine  # noqa: F401


# ---------------------------------------------------------------------------
# property: refcounts drain to zero
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_refcounts_return_to_zero_after_drain(seed):
    """Random admit/lookup/grow/fork/finish/preempt/evict traffic over a
    tiny vocab (maximal prefix collisions): after releasing every slot and
    clearing the index, every page must be back on the free list."""
    rng = np.random.default_rng(seed)
    P = 4
    lay = PagedLayout(page_size=P, num_pages=16, max_slots=4)
    sched = PageScheduler(lay, max_len=24)
    idx = PrefixIndex(sched.alloc, P)
    sched.reclaim = idx.evict
    tick = 0
    for _ in range(60):
        tick += 1
        op = rng.choice(["admit", "grow", "finish", "preempt"])
        if op == "admit" and sched.free_slot() is not None:
            plen = int(rng.integers(2, 12))
            prompt = rng.integers(0, 3, plen).astype(np.int32)
            shared = idx.lookup(0, prompt[:plen - 1])
            sched.admit(_req(prompt), plen, tick, shared=shared)
        elif op == "grow" and sched.active():
            s = int(rng.choice(sched.active()))
            new_len = int(sched.lens[s]) + 1
            if new_len < 24 and sched.ensure(s, new_len, protect=[s]):
                sched.lens[s] = new_len
        elif op == "finish" and sched.active():
            s = int(rng.choice(sched.active()))
            stt = sched.slots[s]
            toks = stt.req.prompt
            if int(sched.lens[s]) >= len(toks):
                idx.register(0, toks[:(len(toks) // P) * P],
                             stt.pages, tick)
                if len(toks) % P:
                    idx.register_tail(0, toks, stt.pages[len(toks) // P],
                                      tick)
                sched.release(s)
        elif op == "preempt" and sched.active():
            sched.preempt(int(rng.choice(sched.active())))
        sched.take_forks()
        sched.drain_evicted()
    for s in sched.active():
        sched.release(s)
    idx.clear()
    assert sched.alloc.free_pages == lay.num_pages
    assert sched.alloc.shared_pages == 0
    sched.alloc.check_invariants()
