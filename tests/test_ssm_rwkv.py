"""Mamba & RWKV blocks: chunked-scan correctness, decode/prefill state
continuity, hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare container — CI installs the real thing
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduce_config
from repro.models import rwkv as rwkv_mod, ssm

KEY = jax.random.PRNGKey(2)


def _step_scan_oracle(dt, Bc, Cc, xi, A, h0):
    """Per-step sequential oracle for the selective scan."""
    B, T, D = dt.shape
    h = h0
    ys = []
    for t in range(T):
        a = jnp.exp(dt[:, t, :, None] * A)
        bx = (dt[:, t] * xi[:, t])[..., None] * Bc[:, t][:, None, :]
        h = a * h + bx
        ys.append(jnp.einsum("bdn,bn->bd", h, Cc[:, t]))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_selective_scan_chunk_invariance(chunk):
    B, T, D, N = 2, 50, 8, 4
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, D)))
    Bc = jax.random.normal(ks[1], (B, T, N))
    Cc = jax.random.normal(ks[2], (B, T, N))
    xi = jax.random.normal(ks[3], (B, T, D))
    A = -jnp.exp(jax.random.normal(ks[4], (D, N)) * 0.3)
    h0 = jnp.zeros((B, D, N))
    y_ref, h_ref = _step_scan_oracle(dt, Bc, Cc, xi, A, h0)
    y, h = ssm._selective_scan(dt, Bc, Cc, xi, A, h0, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-4,
                               atol=1e-4)


def test_mamba_block_decode_continuation():
    cfg = reduce_config(get_config("jamba-1.5-large-398b"))
    p = ssm.init_mamba(cfg, KEY, jnp.float32)
    B, T = 2, 12
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, cfg.d_model))
    y_full, _ = ssm.apply_mamba_block(cfg, p, x)
    # prefill 8 + decode 4 must match
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    cache = {"conv": jnp.zeros((B, mc.d_conv - 1, d_in)),
             "ssm": jnp.zeros((B, d_in, mc.d_state))}
    y_pf, cache = ssm.apply_mamba_block(cfg, p, x[:, :8], cache=cache)
    ys = [y_pf]
    for t in range(8, T):
        y_t, cache = ssm.apply_mamba_block(cfg, p, x[:, t:t + 1], cache=cache)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 32, 128])
def test_wkv_chunk_invariance(chunk):
    B, T, H, N = 1, 40, 2, 8
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N)))
    u = jax.random.normal(ks[4], (H, N)) * 0.2
    s0 = jnp.zeros((B, H, N, N))
    y1, s1 = rwkv_mod.wkv_scan(r, k, v, w, u, s0, chunk=chunk)
    y2, s2 = rwkv_mod.wkv_scan(r, k, v, w, u, s0, chunk=1024)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)


def test_wkv_grads_through_chunked_checkpoint():
    B, T, H, N = 1, 32, 2, 4
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N)))
    u = jax.random.normal(ks[4], (H, N)) * 0.2
    s0 = jnp.zeros((B, H, N, N))

    def loss(r, k, v, w, chunk):
        y, s = rwkv_mod.wkv_scan(r, k, v, w, u, s0, chunk=chunk)
        return jnp.sum(y ** 2) + jnp.sum(s ** 2)

    g8 = jax.grad(loss, (0, 1, 2, 3))(r, k, v, w, 8)
    gfull = jax.grad(loss, (0, 1, 2, 3))(r, k, v, w, 1024)
    for a, b in zip(g8, gfull):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_rwkv_block_decode_continuation():
    cfg = reduce_config(get_config("rwkv6-7b"))
    p = rwkv_mod.init_rwkv(cfg, KEY, jnp.float32)
    B, T = 1, 10
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, cfg.d_model))
    y_full, _ = rwkv_mod.apply_rwkv_block(cfg, p, x)
    H = cfg.d_model // cfg.rwkv.head_dim
    cache = {"shift_t": jnp.zeros((B, cfg.d_model)),
             "shift_c": jnp.zeros((B, cfg.d_model)),
             "wkv": jnp.zeros((B, H, cfg.rwkv.head_dim, cfg.rwkv.head_dim))}
    ys = []
    for t in range(T):
        y_t, cache = rwkv_mod.apply_rwkv_block(cfg, p, x[:, t:t + 1],
                                               cache=cache)
        ys.append(y_t)
    y_inc = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_wkv_decay_contracts_state(seed):
    """With k=0 the state must contract monotonically (w in (0,1))."""
    B, T, H, N = 1, 16, 1, 4
    key = jax.random.PRNGKey(seed)
    r = jnp.zeros((B, T, H, N))
    k = jnp.zeros((B, T, H, N))
    v = jnp.zeros((B, T, H, N))
    w = jax.nn.sigmoid(jax.random.normal(key, (B, T, H, N))) * 0.98 + 0.01
    u = jnp.zeros((H, N))
    s0 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (B, H, N, N)))
    _, s_fin = rwkv_mod.wkv_scan(r, k, v, w, u, s0)
    assert bool(jnp.all(jnp.abs(s_fin) <= jnp.abs(s0) + 1e-6))
