"""Multi-device behaviour (8 fake CPU devices via subprocess so the main
test process keeps exactly one device)."""
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + code)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """(data=2, model=4) sharded train step == single-device numerics."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train.steps import TrainHParams, make_train_step
from repro.optim.adamw import AdamWConfig

cfg = reduce_config(get_config("gemma2-9b"), d_model=64, n_heads=4, d_ff=128, vocab=256)
key = jax.random.PRNGKey(0)
params = tfm.init_params(cfg, key, moe_parallel=1)
lora = lora_lib.init_lora_params(cfg, key)
toks = jax.random.randint(key, (8, 65), 0, cfg.vocab_size)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
hp = TrainHParams(adamw=AdamWConfig(lr=1e-2, grad_clip=None))

# single-device reference
step1 = make_train_step(cfg, tfm.ExecConfig(capacity_factor=8.0), hp)
l1, _, m1 = step1(params, lora, adamw.init(lora), batch, key)

# sharded
mesh = make_mesh((2, 4), ("data", "model"))
axes = shd.axes_for(mesh)
ec = tfm.ExecConfig(capacity_factor=8.0,
                    sharder=shd.make_sharder(mesh, axes, "train"),
                    moe_group_size=16, block_q=16)
stepN = make_train_step(cfg, ec, hp)
with mesh:
    shardings = shd.params_shardings(cfg, jax.eval_shape(lambda: params), mesh, axes, "train")
    params_s = jax.device_put(params, shardings)
    l2, _, m2 = jax.jit(stepN)(params_s, lora, adamw.init(lora), batch, key)
print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(l1), jax.tree.leaves(l2)))
print("max lora delta", d)
assert d < 2e-3
print("OK")
""")
    assert "OK" in out


def test_compressed_allreduce_multidev():
    out = _run("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.dist.compression import make_compressed_allreduce
mesh = make_mesh((8,), ("dp",))
ar = make_compressed_allreduce(mesh, "dp")
key = jax.random.PRNGKey(0)
g = {"a": jax.random.normal(key, (4097,)), "b": jax.random.normal(key, (13, 7))}
avg, err = ar(g)
rel = max(float(jnp.max(jnp.abs(avg[k] - g[k]))) for k in g) / 4.0
assert rel < 2e-2, rel
# error feedback: second round still bounded
avg2, err2 = ar(g, err)
print("OK")
""")
    assert "OK" in out


def test_pipeline_parallel_multidev():
    out = _run("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.dist.pp import gpipe
mesh = make_mesh((4, 2), ("stage", "data"))
n_stages, n_micro, mb, d = 4, 6, 4, 16
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (n_stages, d, d)) * 0.5
x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))
f = gpipe(lambda W, x: jnp.tanh(x @ W), mesh, "stage", n_micro)
y = f(Ws, x)
ref = x
for i in range(n_stages):
    ref = jnp.tanh(ref @ Ws[i])
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 1e-6, err
print("OK")
""")
    assert "OK" in out


def test_decode_sharded_matches_single():
    out = _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import transformer as tfm
from repro.models.kvcache import init_cache, cache_spec_structs

cfg = reduce_config(get_config("internlm2-20b"), d_model=64, n_heads=4, vocab=256)
key = jax.random.PRNGKey(0)
params = tfm.init_params(cfg, key)
B, T = 8, 12
toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)

# reference: unsharded prefill+decode
cache = init_cache(cfg, B, 32, kv_dtype=jnp.float32)
_, cache, _ = tfm.forward(cfg, params, {"tokens": toks}, mode="prefill",
                          prefill_cache_len=32, cache=cache)
l_ref, _, _ = tfm.forward(cfg, params, {"tokens": toks[:, -1:]*0+5},
                          mode="decode", cache=cache)

mesh = make_mesh((2, 4), ("data", "model"))
axes = shd.axes_for(mesh)
ec = tfm.ExecConfig(sharder=shd.make_sharder(mesh, axes, "decode"))
with mesh:
    cache_sh = jax.device_put(cache, jax.tree.map(
        lambda l: l.sharding if hasattr(l, "sharding") else None,
        cache_spec_structs(cfg, B, 32, jnp.float32,
                           shd.cache_shardings(cfg, mesh, axes))))
    l_sh = jax.jit(lambda p, c, t: tfm.forward(
        cfg, p, {"tokens": t}, mode="decode", cache=c, exec_cfg=ec)[0])(
        params, cache_sh, toks[:, -1:]*0+5)
err = float(jnp.max(jnp.abs(l_ref - l_sh)))
assert err < 2e-4, err
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_mini_dryrun_8dev():
    """The dry-run machinery itself on a small mesh: lower+compile+analyze."""
    out = _run("""
import jax
from repro.configs import get_config, SHAPES
from repro.launch.specs import build_cell
from repro.launch.mesh import make_mesh
from repro.roofline.hlo_parse import HloModule
from repro.configs.base import ModelConfig
import dataclasses

cfg = get_config("llama3.2-1b")
cfg = dataclasses.replace(cfg, n_layers=4)
shape = SHAPES["train_4k"]
shape = dataclasses.replace(shape, global_batch=8, seq_len=512)
mesh = make_mesh((2, 4), ("data", "model"))
cell = build_cell(cfg, shape, mesh)
with mesh:
    compiled = jax.jit(cell.step).lower(*cell.args).compile()
cost = HloModule(compiled.as_text(), tpu_dtypes=True).entry_cost()
assert cost.flops > 1e9 and cost.bytes > 1e6
print("OK", cost.flops)
""")
    assert "OK" in out
