"""Roofline HLO parser: exact flops on known programs, while-trip
multiplication, collective wire-byte factors."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_parse import HloModule


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return HloModule(txt).entry_cost()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _cost(lambda a, b: a @ b, a, b)
    assert c.flops == pytest.approx(2 * 64 * 128 * 32)


def test_while_trip_count_multiplies():
    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    flops = {}
    for L in (4, 8):
        w = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
        flops[L] = _cost(f, w, x).flops
    # layer matmul flops must double with depth
    per_layer = 2 * 16 * 64 * 64
    assert flops[8] - flops[4] == pytest.approx(4 * per_layer, rel=0.05)


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    c = _cost(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    assert c.flops == pytest.approx(2 * 4 * 8 * 16 * 8)


def test_bytes_reasonable_for_elementwise():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _cost(lambda a: jnp.tanh(a) + 1.0, a)
    nbytes = 1024 * 1024 * 4
    # read + write, allow fusion-boundary slack
    assert nbytes * 1.5 <= c.bytes <= nbytes * 4


def test_tpu_dtype_mode_halves_f32():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    txt = jax.jit(lambda a: jnp.tanh(a) * 2.0).lower(a).compile().as_text()
    raw = HloModule(txt).entry_cost().bytes
    corr = HloModule(txt, tpu_dtypes=True).entry_cost().bytes
    assert corr == pytest.approx(raw / 2, rel=0.01)
