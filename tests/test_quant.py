"""Crossbar-wise quantization: property tests (hypothesis) + MnFm trees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare container — CI installs the real thing
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import quant
from repro.models.transformer import init_params

shapes = st.tuples(st.integers(1, 300), st.integers(1, 300))


@settings(max_examples=25, deadline=None)
@given(shape=shapes, bits=st.sampled_from([8, 4]), seed=st.integers(0, 2**16))
def test_roundtrip_error_bound(shape, bits, seed):
    """|w - dequant(quant(w))| <= absmax/qmax / 2 per (128,128) block."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=shape) * rng.uniform(0.01, 10), jnp.float32)
    qt = quant.quantize(w, bits)
    wd = quant.dequantize(qt, jnp.float32)
    assert wd.shape == w.shape
    # per-block bound: half a quantization step
    b = qt.block
    qmax = quant.INT_MAX[bits]
    pi, pj = ((shape[0] + b - 1) // b) * b, ((shape[1] + b - 1) // b) * b
    wp = jnp.pad(w, ((0, pi - shape[0]), (0, pj - shape[1])))
    blocks = wp.reshape(pi // b, b, pj // b, b)
    absmax = jnp.max(jnp.abs(blocks), axis=(1, 3))
    step = absmax / qmax
    err = jnp.abs(wd - w)
    errp = jnp.pad(err, ((0, pi - shape[0]), (0, pj - shape[1])))
    err_blocks = jnp.max(errp.reshape(pi // b, b, pj // b, b), axis=(1, 3))
    # half-step bound with an ulp allowance: w/scale is computed in f32,
    # so the rounding threshold can land one ulp past .5 for large absmax
    assert bool(jnp.all(err_blocks <= step * (0.5 + 1e-5) + 1e-6))


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(2, 64).map(lambda x: x * 2), cols=st.integers(1, 64),
       seed=st.integers(0, 2**16))
def test_pack4_roundtrip(rows, cols, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(-7, 8, size=(rows, cols)), jnp.int8)
    packed = quant._pack4(codes)
    assert packed.shape == (rows // 2, cols)
    un = quant._unpack4(packed)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(codes))


@settings(max_examples=10, deadline=None)
@given(lead=st.integers(1, 4), seed=st.integers(0, 100))
def test_batched_leading_dims(lead, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(lead, 130, 70)), jnp.float32)
    qt = quant.quantize(w, 8)
    wd = quant.dequantize(qt, jnp.float32)
    assert wd.shape == w.shape
    assert float(jnp.max(jnp.abs(wd - w))) < 0.2


def test_quantize_is_deterministic_and_symmetric():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(128, 128)), jnp.float32)
    q1, q2 = quant.quantize(w, 8), quant.quantize(w, 8)
    np.testing.assert_array_equal(np.asarray(q1.codes), np.asarray(q2.codes))
    qn = quant.quantize(-w, 8)
    np.testing.assert_array_equal(np.asarray(qn.codes), -np.asarray(q1.codes))


@pytest.mark.parametrize("tag,mha,ff", [("M8F8", 8, 8), ("M8F4", 8, 4),
                                        ("M4F8", 4, 8)])
def test_mnfm_tree_application(tag, mha, ff):
    cfg = reduce_config(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    qc = QuantConfig(mha_bits=mha, ff_bits=ff)
    qp = quant.quantize_params(params, qc, min_size=1)
    attn = qp["layers"][0]["attn"]
    ffp = qp["layers"][0]["ff"]
    for name in ("wq", "wk", "wv", "wo"):
        assert quant.is_quantized(attn[name]) == (mha < 16)
        if quant.is_quantized(attn[name]):
            assert attn[name].bits == mha
    for name in ("w1", "w2", "w3"):
        assert quant.is_quantized(ffp[name]) and ffp[name].bits == ff
    # embeddings & norms never quantized
    assert not quant.is_quantized(qp["embed"]["table"])
    assert not quant.is_quantized(qp["final_norm"]["scale"])


def test_quantization_error_monotone_in_bits():
    w = jnp.asarray(np.random.default_rng(1).normal(size=(256, 256)), jnp.float32)
    e8 = float(quant.quantization_error(w, 8))
    e4 = float(quant.quantization_error(w, 4))
    e2 = float(quant.quantization_error(w, 2))
    assert e8 < e4 < e2
    assert e8 < 0.01 and e4 < 0.25


def test_m4f4_failure_mode_reproduced():
    """Paper Fig. 13: one scale per 128x128 crossbar at 4 bits gives coarse
    bins; with heavy-tailed weights the relative error becomes large."""
    rng = np.random.default_rng(2)
    w = rng.standard_t(df=2, size=(128, 128)).astype(np.float32)  # heavy tails
    e4 = float(quant.quantization_error(jnp.asarray(w), 4))
    e8 = float(quant.quantization_error(jnp.asarray(w), 8))
    assert e4 > 5 * e8
