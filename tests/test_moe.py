"""MoE: dispatch/combine vs dense oracle, slot-TP layout, grouping, drops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import moe

KEY = jax.random.PRNGKey(11)


def _cfg(arch="mixtral-8x22b"):
    return reduce_config(get_config(arch))


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "llama4-scout-17b-a16e",
                                  "jamba-1.5-large-398b"])
@pytest.mark.parametrize("moe_parallel", [1, 8])
def test_moe_matches_dense_oracle(arch, moe_parallel):
    cfg = _cfg(arch)
    p = moe.init_moe(cfg, KEY, jnp.float32, moe_parallel=moe_parallel)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 16, cfg.d_model))
    y, aux = moe.apply_moe(cfg, p, x, capacity_factor=32.0)
    yref = moe.ref_moe(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=2e-5,
                               atol=2e-5)
    assert float(aux["lb_loss"]) > 0


def test_group_size_invariance_without_drops():
    cfg = _cfg()
    p = moe.init_moe(cfg, KEY, jnp.float32, moe_parallel=4)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, cfg.d_model))
    y1, _ = moe.apply_moe(cfg, p, x, capacity_factor=32.0, group_size=None)
    y2, _ = moe.apply_moe(cfg, p, x, capacity_factor=32.0, group_size=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-5)


def test_capacity_drops_zero_residual():
    """With capacity factor ~0 every token is dropped -> MoE output ~ 0
    (shared expert excluded)."""
    cfg = _cfg()
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 16, cfg.d_model))
    y, _ = moe.apply_moe(cfg, p, x, capacity_factor=1e-9)
    # capacity >= 1 enforced, so only the first token per slot survives;
    # most outputs are exactly zero
    zero_rows = float(jnp.mean(jnp.all(y == 0.0, axis=-1)))
    assert zero_rows > 0.5


def test_slot_tp_equivalence():
    """tpe > 1 (expert-ff split across slots) must equal tpe == 1 exactly."""
    cfg = _cfg()
    E = cfg.moe.n_experts
    p1 = moe.init_moe(cfg, KEY, jnp.float32, moe_parallel=1)     # slots == E
    # re-layout p1 into 2 slots per expert
    def split(w, axis):
        parts = jnp.split(w, 2, axis=axis)   # per expert halves
        return jnp.stack([h for pair in zip(*[jnp.split(x, w.shape[0], 0)
                                              for x in parts])
                          for h in pair]).squeeze(1)
    w1 = jnp.concatenate([jnp.stack([p1["w1"][e, :, :cfg.d_ff // 2],
                                     p1["w1"][e, :, cfg.d_ff // 2:]])
                          for e in range(E)])
    w3 = jnp.concatenate([jnp.stack([p1["w3"][e, :, :cfg.d_ff // 2],
                                     p1["w3"][e, :, cfg.d_ff // 2:]])
                          for e in range(E)])
    w2 = jnp.concatenate([jnp.stack([p1["w2"][e, :cfg.d_ff // 2],
                                     p1["w2"][e, cfg.d_ff // 2:]])
                          for e in range(E)])
    p2 = {"router": p1["router"], "w1": w1, "w2": w2, "w3": w3}
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 8, cfg.d_model))
    y1, _ = moe.apply_moe(cfg, p1, x, capacity_factor=32.0)
    y2, _ = moe.apply_moe(cfg, p2, x, capacity_factor=32.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-5)


def test_shared_expert_always_on():
    cfg = _cfg("llama4-scout-17b-a16e")
    assert cfg.moe.shared_expert
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 8, cfg.d_model))
    y_with, _ = moe.apply_moe(cfg, p, x, capacity_factor=1e-9)
    # even with all routed tokens dropped, the shared expert contributes
    assert float(jnp.mean(jnp.abs(y_with))) > 1e-4


# ---------------------------------------------------------------------------
# capacity arithmetic
# ---------------------------------------------------------------------------


def test_capacity_exact_ceil_boundary():
    """Regression: the old ``int(x*cf + 0.999)`` pseudo-ceil under-allocated
    whenever the true quotient's fractional part fell in (0, 0.001) —
    4001 tokens over 2000 slots at cf=1.0 is 2.0005 rows, which needs 3."""
    cfg = _cfg()
    assert moe._capacity(cfg, 4001, 1, 2000, 1.0) == 3
    # exact integers must NOT round up
    assert moe._capacity(cfg, 4000, 1, 2000, 1.0) == 2
    assert moe._capacity(cfg, 16, 2, 8, 1.0) == 4
    # floor of 1 row survives tiny factors
    assert moe._capacity(cfg, 16, 1, 8, 1e-9) == 1


# ---------------------------------------------------------------------------
# dropless dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "llama4-scout-17b-a16e"])
@pytest.mark.parametrize("moe_parallel", [1, 8])
def test_dropless_matches_dense_oracle(arch, moe_parallel):
    """Dropless ignores capacity_factor entirely: even a factor that would
    drop every token under capacity dispatch routes exactly."""
    cfg = _cfg(arch)
    p = moe.init_moe(cfg, KEY, jnp.float32, moe_parallel=moe_parallel)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 16, cfg.d_model))
    y, aux = moe.apply_moe(cfg, p, x, dispatch="dropless",
                           capacity_factor=1e-9)
    yref = moe.ref_moe(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=2e-5,
                               atol=2e-5)
    assert float(aux["dropped_tokens"]) == 0.0


def test_dropless_equals_capacity_when_sufficient():
    cfg = _cfg()
    p = moe.init_moe(cfg, KEY, jnp.float32, moe_parallel=4)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 16, cfg.d_model))
    yd, _ = moe.apply_moe(cfg, p, x, dispatch="dropless")
    yc, auxc = moe.apply_moe(cfg, p, x, dispatch="capacity",
                             capacity_factor=32.0)
    assert float(auxc["dropped_tokens"]) == 0.0
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), rtol=2e-5,
                               atol=2e-5)


def test_dropless_token_mask_and_groups():
    """Masked (padded) tokens neither claim ranks nor perturb real rows,
    and per-group dispatch stays exact."""
    cfg = _cfg()
    p = moe.init_moe(cfg, KEY, jnp.float32, moe_parallel=4)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 16, cfg.d_model))
    yref = moe.ref_moe(cfg, p, x)
    tm = jnp.ones((2, 16), bool).at[:, 10:].set(False)
    ym, _ = moe.apply_moe(cfg, p, x, dispatch="dropless", token_mask=tm)
    np.testing.assert_allclose(np.asarray(ym[:, :10]), np.asarray(yref[:, :10]),
                               rtol=2e-5, atol=2e-5)
    yg, _ = moe.apply_moe(cfg, p, x, dispatch="dropless", group_size=4)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yref), rtol=2e-5,
                               atol=2e-5)


def test_dropped_tokens_counter():
    """Capacity dispatch reports real (token, expert) drops; dropless
    reports zero on the same inputs."""
    cfg = _cfg()
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (1, 16, cfg.d_model))
    _, aux_cap = moe.apply_moe(cfg, p, x, capacity_factor=1e-9)
    # capacity floor is 1 row/slot: 16 tokens * top_k assignments minus at
    # most one survivor per slot must drop
    assert float(aux_cap["dropped_tokens"]) >= 16 * cfg.moe.top_k \
        - cfg.moe.n_experts
    _, aux_dl = moe.apply_moe(cfg, p, x, dispatch="dropless",
                              capacity_factor=1e-9)
    assert float(aux_dl["dropped_tokens"]) == 0.0


def test_unknown_dispatch_rejected():
    cfg = _cfg()
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jnp.zeros((1, 4, cfg.d_model))
    with pytest.raises(ValueError, match="dispatch"):
        moe.apply_moe(cfg, p, x, dispatch="bogus")
