"""Noise-aware fine-tuning (Atleus SS V.E).

ReRAM crossbars perturb stored conductances; the paper injects clipped
Gaussian noise dw ~ N(0, sigma^2) into the *frozen pre-trained* weights while
training the LoRA adapters (which live on the noise-free systolic engine), so
the adapters learn to compensate. sigma is set relative to the per-tensor
absolute-maximum weight, and perturbations beyond the absmax bound are
clipped (ref [57] in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class NoiseConfig:
    enabled: bool = False
    sigma_rel: float = 0.02   # sigma = sigma_rel * absmax(w), per tensor
    clip: bool = True         # clip w+dw to [-absmax, absmax]

    def with_sigma(self, sigma_rel: float) -> "NoiseConfig":
        return NoiseConfig(enabled=True, sigma_rel=sigma_rel, clip=self.clip)


def apply_weight_noise(w: Array, cfg: NoiseConfig, rng: Optional[Array]) -> Array:
    """Perturb a frozen weight the way a non-ideal crossbar would."""
    if not cfg.enabled:
        return w
    assert rng is not None, "noise-aware fine-tuning needs an rng key"
    # fold in a shape fingerprint so every weight in a scanned stack gets an
    # independent draw even when the caller passes one key per layer class
    key = jax.random.fold_in(rng, (w.ndim * 1000003 + w.shape[-1]) % (2**31))
    absmax = jnp.max(jnp.abs(w)).astype(jnp.float32)
    sigma = cfg.sigma_rel * absmax
    noisy = w.astype(jnp.float32) + sigma * jax.random.normal(key, w.shape, jnp.float32)
    if cfg.clip:
        noisy = jnp.clip(noisy, -absmax, absmax)
    return noisy.astype(w.dtype)
