"""Crossbar-wise quantization (Atleus SS IV.D).

The paper quantizes frozen pre-trained weights independently per 128x128
ReRAM crossbar with one absmax scale each, runs the MVM on the quantized
codes, and dequantizes **after** accumulation (one shift-and-add per crossbar
output) rather than before compute like a GPU. Here the crossbar becomes an
MXU-aligned (128,128) block: weights live in HBM as int4/int8 codes + an f32
scale per block, and the Pallas ``crossbar_matmul`` kernel applies the block
scale on the f32 accumulator tile (``repro.kernels.crossbar_matmul``). The
pure-XLA fallback dequantizes blockwise just before the einsum (still one
multiply per weight element, fused by XLA into the gather of the codes).

Blocks are taken over the *last two* dims; leading dims (expert slots, layer
stacking) are batch dims. Non-multiple-of-128 dims are zero-padded in the
codes and sliced back at dequant.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

INT_MAX = {8: 127, 4: 7, 2: 1}  # symmetric ranges; 2-bit == the cell resolution


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "scales"],
    meta_fields=["bits", "block", "orig_shape"],
)
@dataclasses.dataclass
class QuantizedTensor:
    """Frozen crossbar-quantized weight. ``codes`` is int8 (4-bit values are
    stored two-per-byte packed along the second-to-last dim); ``scales`` is
    f32 with one entry per (block x block) crossbar."""

    codes: Array
    scales: Array
    bits: int
    block: int
    orig_shape: Tuple[int, ...]

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.orig_shape

    @property
    def ndim(self) -> int:
        return len(self.orig_shape)

    @property
    def dtype(self):  # duck-type as the dequantized dtype
        return jnp.bfloat16

    def nbytes(self) -> int:
        return self.codes.size * self.codes.dtype.itemsize + self.scales.size * 4


def quantize(w: Array, bits: int, block: int = 128) -> QuantizedTensor:
    """Symmetric absmax quantization per (block, block) crossbar."""
    assert bits in INT_MAX, bits
    assert w.ndim >= 2
    orig_shape = tuple(w.shape)
    *lead, di, dj = w.shape
    pi, pj = _ceil_to(di, block), _ceil_to(dj, block)
    if (pi, pj) != (di, dj):
        w = jnp.pad(w, [(0, 0)] * len(lead) + [(0, pi - di), (0, pj - dj)])
    nbi, nbj = pi // block, pj // block
    wb = w.astype(jnp.float32).reshape(*lead, nbi, block, nbj, block)
    absmax = jnp.max(jnp.abs(wb), axis=(-3, -1), keepdims=True)
    qmax = INT_MAX[bits]
    scale = jnp.maximum(absmax, 1e-12) / qmax
    codes = jnp.clip(jnp.round(wb / scale), -qmax, qmax).astype(jnp.int8)
    codes = codes.reshape(*lead, pi, pj)
    scales = scale.squeeze(-1).squeeze(-2).astype(jnp.float32)  # (*lead, nbi, nbj)
    if bits == 4:
        codes = _pack4(codes)
    return QuantizedTensor(codes=codes, scales=scales, bits=bits, block=block,
                           orig_shape=orig_shape)


def _pack4(codes: Array) -> Array:
    """Pack int4 values two-per-byte along the second-to-last dim."""
    *lead, pi, pj = codes.shape
    assert pi % 2 == 0
    c = codes.reshape(*lead, pi // 2, 2, pj).astype(jnp.int32)
    lo = c[..., 0, :] & 0xF
    hi = (c[..., 1, :] & 0xF) << 4
    return (lo | hi).astype(jnp.uint8)


def _unpack4(packed: Array) -> Array:
    *lead, ph, pj = packed.shape
    p = packed.astype(jnp.int32)
    lo = (p & 0xF)
    hi = (p >> 4) & 0xF
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-2)  # (*lead, ph, 2, pj)
    return out.reshape(*lead, ph * 2, pj).astype(jnp.int8)


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16) -> Array:
    codes = _unpack4(qt.codes) if qt.bits == 4 else qt.codes
    *lead, pi, pj = codes.shape
    b = qt.block
    nbi, nbj = pi // b, pj // b
    cb = codes.reshape(*lead, nbi, b, nbj, b).astype(jnp.float32)
    w = cb * qt.scales[..., :, None, :, None]
    w = w.reshape(*lead, pi, pj)
    di, dj = qt.orig_shape[-2:]
    if (pi, pj) != (di, dj):
        w = w[..., :di, :dj]
    return w.astype(dtype)


def quantization_error(w: Array, bits: int, block: int = 128) -> Array:
    """Relative Frobenius error of the crossbar quantizer (used by the Fig.13
    perplexity benchmark and property tests)."""
    qt = quantize(w, bits, block)
    wd = dequantize(qt, jnp.float32)
    return jnp.linalg.norm(w - wd) / jnp.maximum(jnp.linalg.norm(w), 1e-12)


def is_quantized(x) -> bool:
    return isinstance(x, QuantizedTensor)


def maybe_dequantize(x, dtype=jnp.bfloat16) -> Array:
    return dequantize(x, dtype) if is_quantized(x) else x


# ---------------------------------------------------------------------------
# MnFm application over a parameter tree
# ---------------------------------------------------------------------------

# weight-name -> quantization class ("mha" | "ff" | None). Mamba/RWKV
# projections are mapped per DESIGN.md SS5 (time-mix/ssm -> mha class,
# channel-mix/ff -> ff class). Embeddings / norms / LoRA are never quantized.
WEIGHT_CLASS = {
    "wq": "mha", "wk": "mha", "wv": "mha", "wo": "mha",
    "w1": "ff", "w2": "ff", "w3": "ff",
    "router": None,                     # tiny; stays high precision
    "in_proj": "mha", "out_proj": "mha", "x_proj": None, "dt_proj": None,
    "r_proj": "mha", "k_proj": "mha", "v_proj": "mha", "g_proj": "mha",
    "o_proj": "mha",
    "ck_proj": "ff", "cv_proj": "ff",   # rwkv channel-mix
}


def quantize_params(params, quant_cfg, *, min_size: int = 1 << 16):
    """Apply MnFm crossbar-wise quantization to a base parameter tree.

    Walks the tree by key path; leaves whose terminal key is in WEIGHT_CLASS
    get the class' bit width (16 = leave in original precision)."""
    bits_for = {"mha": quant_cfg.mha_bits, "ff": quant_cfg.ff_bits}

    def visit(path, leaf):
        if not isinstance(leaf, jax.Array) or leaf.ndim < 2 or leaf.size < min_size:
            return leaf
        key = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                key = p.key
                break
        cls = WEIGHT_CLASS.get(key)
        if cls is None:
            return leaf
        bits = bits_for[cls]
        if bits >= 16:
            return leaf
        return quantize(leaf, bits, quant_cfg.block)

    return jax.tree_util.tree_map_with_path(visit, params)


def dequantize_params(params, dtype=jnp.bfloat16):
    return jax.tree.map(lambda x: maybe_dequantize(x, dtype),
                        params, is_leaf=is_quantized)
