"""LoRA / QLoRA (Atleus SS III.B, Eq. 1/4).

Y = W0·X + (alpha/r)·A·B·X with W0 frozen (and crossbar-quantized under
QLoRA); only A/B train. On Atleus the A/B matmuls run on the systolic array
(DYNAMIC engine); here they run on the bf16 MXU path via
``hetero.dynamic_matmul``.

The LoRA parameter tree mirrors the model's scan layout: one entry per
scan-period position, leaves stacked over periods, so it zips with the base
params inside ``lax.scan``. Multi-adapter serving (paper SS V.G: "inferencing
on different tasks by just loading LoRA parameters") stacks whole adapter
trees along a leading dim and gathers per-request.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero

Array = jax.Array

# target name -> (path inside the per-position param tree) per block kind.
# rwkv has no attention; the paper's W_Q/W_V targets translate to the
# receptance/value time-mix projections (DESIGN.md SS5).
TARGET_PATHS = {
    "attn": {"wq": ("attn", "wq"), "wk": ("attn", "wk"),
             "wv": ("attn", "wv"), "wo": ("attn", "wo")},
    "rwkv": {"wq": ("time_mix", "r_proj"), "wk": ("time_mix", "k_proj"),
             "wv": ("time_mix", "v_proj"), "wo": ("time_mix", "o_proj")},
    "mamba": {"mamba_in": ("in_proj",), "mamba_out": ("out_proj",)},
}


def _targets_for(cfg: ModelConfig, kind: str) -> Dict[str, Tuple[str, ...]]:
    paths = TARGET_PATHS.get(kind, {})
    return {t: paths[t] for t in cfg.lora.targets if t in paths}


def _weight_shape(cfg: ModelConfig, kind: str, target: str) -> Tuple[int, int]:
    d = cfg.d_model
    if kind in ("attn", "rwkv"):
        if kind == "rwkv":
            return (d, d)
        return {"wq": (d, cfg.q_dim), "wk": (d, cfg.kv_dim),
                "wv": (d, cfg.kv_dim), "wo": (cfg.q_dim, d)}[target]
    if kind == "mamba":
        d_in = cfg.mamba.expand * d
        return {"mamba_in": (d, 2 * d_in), "mamba_out": (d_in, d)}[target]
    raise KeyError((kind, target))


def scan_period(cfg: ModelConfig) -> int:
    """Scan period = lcm(block period, moe period, attn-pattern period in
    global layers) so every scanned position has static behaviour."""
    import math
    p = cfg.period
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.period)
    n_attn_pat = len(cfg.attn.pattern)
    if "attn" in cfg.block_pattern and n_attn_pat > 1:
        p = math.lcm(p, cfg.period * n_attn_pat)
    assert cfg.n_layers % p == 0, (cfg.name, p)
    return p


def init_lora_params(cfg: ModelConfig, key: Array, dtype=jnp.float32):
    """A ~ N(0, 0.02), B = 0 (delta starts at zero). Leaves are stacked
    (n_scan_periods, d_in, r) / (n_scan_periods, r, d_out)."""
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    r = cfg.lora.rank
    layers = []
    for pos in range(p):
        kind = cfg.block_kind(pos)
        entry = {}
        for t, _path in _targets_for(cfg, kind).items():
            din, dout = _weight_shape(cfg, kind, t)
            key, ka = jax.random.split(key)
            entry[t] = {
                "a": (0.02 * jax.random.normal(ka, (n_sp, din, r))).astype(dtype),
                "b": jnp.zeros((n_sp, r, dout), dtype),
            }
        layers.append(entry)
    return {"layers": tuple(layers)}


def lora_delta(x: Array, ab: Dict[str, Array], scale: float,
               adapter_idx: Optional[Array] = None) -> Array:
    """(alpha/r) * (x @ A) @ B on the DYNAMIC engine.

    ``ab['a']``: (d_in, r) or (n_adapters, d_in, r) with ``adapter_idx``
    (batch,) for batched multi-adapter serving."""
    a, b = ab["a"], ab["b"]
    if adapter_idx is not None:
        a = a[adapter_idx]  # (B, d_in, r)
        b = b[adapter_idx]  # (B, r, d_out)
        xa = hetero.dynamic_einsum("btd,bdr->btr", x, a.astype(x.dtype))
        out = hetero.dynamic_einsum("btr,brd->btd", xa, b.astype(x.dtype))
    else:
        xa = hetero.dynamic_matmul(x, a.astype(x.dtype))
        out = hetero.dynamic_matmul(xa, b.astype(x.dtype))
    return (scale * out).astype(x.dtype)


def lora_scale(cfg: ModelConfig) -> float:
    return cfg.lora.alpha / cfg.lora.rank


def merge_lora(cfg: ModelConfig, base_params, lora_params):
    """Fold adapters into the base weights: W <- W0 + (alpha/r)·A·B.
    Quantized leaves are dequantized first (merging defeats QLoRA storage;
    used for export / equivalence tests)."""
    from repro.core import quant

    p = scan_period(cfg)
    scale = lora_scale(cfg)
    merged_layers = []
    for pos in range(p):
        entry = dict(base_params["layers"][pos])
        kind = cfg.block_kind(pos)
        paths = _targets_for(cfg, kind)
        for t, path in paths.items():
            if t not in lora_params["layers"][pos]:
                continue
            ab = lora_params["layers"][pos][t]
            delta = scale * jnp.einsum(
                "ldr,lrk->ldk", ab["a"].astype(jnp.float32),
                ab["b"].astype(jnp.float32))
            entry = _updated(entry, path, delta, scale)
        merged_layers.append(entry)
    out = dict(base_params)
    out["layers"] = tuple(merged_layers)
    return out


def _updated(tree, path, delta, scale):
    from repro.core import quant as q

    if len(path) == 1:
        w = tree[path[0]]
        wd = q.maybe_dequantize(w, jnp.float32) if q.is_quantized(w) else w.astype(jnp.float32)
        new = (wd + delta).astype(jnp.bfloat16 if q.is_quantized(w) else w.dtype)
        t = dict(tree)
        t[path[0]] = new
        return t
    t = dict(tree)
    t[path[0]] = _updated(tree[path[0]], path[1:], delta, scale)
    return t


def stack_adapters(adapters):
    """Stack N adapter trees for batched multi-adapter serving.

    The stack axis is 1 (leaves are (n_sp, d_in, r) -> (n_sp, n_ad, d_in, r))
    so the layer-scan still slices the leading scan-period dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *adapters)


def count_params(lora_params) -> int:
    return sum(x.size for x in jax.tree.leaves(lora_params))
