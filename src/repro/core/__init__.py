from repro.core import hetero, lora, noise, quant  # noqa: F401
