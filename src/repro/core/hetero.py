"""Heterogeneous compute mapping (Atleus SS IV.A, Eqs. 2-3, 5).

Every matrix multiplication in the model is classified by operand staticness:

  STATIC   — activation x *frozen* weight (MHA-1/MHA-4/FF-1/FF-2, mamba &
             rwkv projections). On Atleus these run on weight-stationary
             ReRAM crossbars; here they take the quantized crossbar path
             (``crossbar_matmul`` Pallas kernel on TPU, blockwise-dequant
             einsum under XLA) and are eligible for crossbar-wise
             quantization + noise injection.
  DYNAMIC  — activation x activation (MHA-2 QK^T, MHA-3 PV, ssm/rwkv
             recurrences) or activation x *trainable* weight (LoRA A/B).
             On Atleus these run on the OS-dataflow systolic array; here
             they stay on the bf16 MXU path (fused flash-attention kernel
             for MHA-2/3).

A trace-time tally (`tally()`) accumulates per-class FLOPs so tests and the
Fig. 7 benchmark can check the paper's Eq. 5 ratio (>90% of MM on the static
engine) directly against the model as built, not just analytically.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.noise import NoiseConfig, apply_weight_noise

Array = jax.Array

STATIC = "static"     # -> ReRAM / crossbar path
DYNAMIC = "dynamic"   # -> systolic / MXU bf16 path


class _Tally(threading.local):
    def __init__(self):
        self.active: Optional[Dict[str, float]] = None


_TALLY = _Tally()


@contextlib.contextmanager
def tally():
    """Collect per-engine-class FLOPs while tracing a function.

    Shapes are static under jit, so accumulating at trace time gives exact
    analytic counts for the traced computation."""
    prev = _TALLY.active
    _TALLY.active = {STATIC: 0.0, DYNAMIC: 0.0, "nonlinear": 0.0}
    try:
        yield _TALLY.active
    finally:
        _TALLY.active = prev


def _record(cls: str, flops: float) -> None:
    if _TALLY.active is not None:
        _TALLY.active[cls] += float(flops)


def record_nonlinear(elements: int) -> None:
    """Softmax / layernorm / activation element counts (MHA-3, L-1, L-2)."""
    _record("nonlinear", float(elements))


def _matmul_flops(x_shape, w_shape) -> float:
    # batched x (..., m, k) @ w (..., k, n): 2*m*k*n * prod(batch)
    k, n = w_shape[-2], w_shape[-1]
    m = 1
    for d in x_shape[:-1]:
        m *= d
    return 2.0 * m * k * n


def static_matmul(x: Array, w, *, noise: Optional[NoiseConfig] = None,
                  rng: Optional[Array] = None, precision=None) -> Array:
    """Activation x frozen-weight matmul — the ReRAM/crossbar path.

    ``w`` may be a raw array or a ``QuantizedTensor`` (crossbar-wise
    quantized). Dequantization happens post-MVM on the hardware; under XLA
    we dequantize blockwise at use (memory traffic still reflects the low-bit
    residency since the codes are what lives in HBM/at rest)."""
    if quant.is_quantized(w):
        wd = quant.dequantize(w, x.dtype)
    else:
        wd = w.astype(x.dtype)
    if noise is not None and noise.enabled:
        wd = apply_weight_noise(wd, noise, rng)
    _record(STATIC, _matmul_flops(x.shape, wd.shape))
    return jax.lax.dot_general(
        x, wd, (((x.ndim - 1,), (wd.ndim - 2,)), ((), ())),
        precision=precision, preferred_element_type=x.dtype)


def static_einsum(spec: str, x: Array, w, *, noise: Optional[NoiseConfig] = None,
                  rng: Optional[Array] = None) -> Array:
    """Batched activation x frozen-weight einsum on the STATIC engine
    (expert matmuls: the expert/slot dim is a batch dim)."""
    if quant.is_quantized(w):
        wd = quant.dequantize(w, x.dtype)
    else:
        wd = w.astype(x.dtype)
    if noise is not None and noise.enabled:
        wd = apply_weight_noise(wd, noise, rng)
    _record(STATIC, _einsum_flops(spec, (x, wd)))
    return jnp.einsum(spec, x, wd, preferred_element_type=x.dtype)


def dynamic_matmul(x: Array, y: Array, *, contract=None, precision=None,
                   preferred_element_type=None) -> Array:
    """Dynamic-operand matmul — the systolic/MXU path (MHA-2/3, LoRA)."""
    if contract is None:
        contract = (((x.ndim - 1,), (y.ndim - 2,)), ((), ()))
    k = 1
    for d in contract[0][0]:
        k *= x.shape[d]
    m = x.size // k
    n = y.size // k // max(1, _batch_size(y, contract[1][1]))
    _record(DYNAMIC, 2.0 * m * k * n)
    return jax.lax.dot_general(x, y, contract, precision=precision,
                               preferred_element_type=preferred_element_type)


def _batch_size(y, batch_dims) -> int:
    b = 1
    for d in batch_dims:
        b *= y.shape[d]
    return b


def dynamic_einsum(spec: str, *operands, preferred_element_type=None) -> Array:
    """einsum on the DYNAMIC engine, with trace-time flop accounting."""
    _record(DYNAMIC, _einsum_flops(spec, operands))
    return jnp.einsum(spec, *operands,
                      preferred_element_type=preferred_element_type)


def _einsum_flops(spec: str, operands) -> float:
    inputs, out = spec.replace(" ", "").split("->")
    terms = inputs.split(",")
    dim_size: Dict[str, int] = {}
    for term, op in zip(terms, operands):
        for ch, s in zip(term, op.shape):
            dim_size[ch] = s
    total = 1
    for ch, s in dim_size.items():
        total *= s
    return 2.0 * total


@dataclass
class BreakdownReport:
    """Eq. 5 check: MM_ReRAM / MM_systolic for a traced step."""

    static_flops: float
    dynamic_flops: float
    nonlinear_elems: float

    @property
    def static_share(self) -> float:
        tot = self.static_flops + self.dynamic_flops
        return self.static_flops / tot if tot else 0.0

    @property
    def ratio(self) -> float:
        return self.static_flops / max(self.dynamic_flops, 1.0)


def breakdown_of(fn, *args, **kwargs) -> BreakdownReport:
    """Trace ``fn`` abstractly and report the engine-class breakdown."""
    with tally() as t:
        jax.eval_shape(fn, *args, **kwargs)
    return BreakdownReport(t[STATIC], t[DYNAMIC], t["nonlinear"])
