"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    block_pattern=("attn",),
    mlp="gated_silu",
    attn=AttnConfig(pattern=("sliding",), window=4096, rope_theta=1e6),
    moe=MoEConfig(n_experts=8, top_k=2, period=1),
    norm="rmsnorm",
    max_seq_len=65536,
).validate()
