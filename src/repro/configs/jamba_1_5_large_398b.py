"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2 on every other layer; Mamba+attention 1:7
interleave (period-8 blocks: 1 attention + 7 mamba). ~398B total params.
[arXiv:2403.19887; hf]"""
from repro.configs.base import AttnConfig, MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=("attn",) + ("mamba",) * 7,
    mlp="gated_silu",
    attn=AttnConfig(pattern=("full",), rope_theta=1e4),
    moe=MoEConfig(n_experts=16, top_k=2, period=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    norm="rmsnorm",
    max_seq_len=262144,
).validate()
