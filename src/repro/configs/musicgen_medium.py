"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24, i.e. MHA)
d_ff=6144 vocab=2048; decoder-only over EnCodec tokens. The EnCodec
frontend is a STUB per the brief: ``input_specs()`` provides precomputed
frame embeddings. Plain (non-gated) GELU MLP, LayerNorm.
[arXiv:2306.05284; hf]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    mlp="gelu",
    attn=AttnConfig(pattern=("full",), rope_theta=1e4),
    norm="layernorm",
    frontend="embeddings",
    max_seq_len=16384,
).validate()
