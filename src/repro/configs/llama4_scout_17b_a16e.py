"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + always-on shared expert, early-fusion
multimodal (frontend stubbed per brief).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn",),
    mlp="gated_silu",
    attn=AttnConfig(pattern=("full",), rope_theta=5e5, qk_norm=True),
    moe=MoEConfig(n_experts=16, top_k=1, period=1, shared_expert=True,
                  router_norm_topk=False),
    norm="rmsnorm",
    max_seq_len=131072,
).validate()
