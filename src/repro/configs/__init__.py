"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (AttnConfig, LoRAConfig, MambaConfig,
                                ModelConfig, MoEConfig, QuantConfig,
                                RWKVConfig, reduce_config)
from repro.configs.shapes import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                  PREFILL_32K, SHAPES, TRAIN_4K, ShapeSuite,
                                  cell_supported)

_ARCH_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "internlm2-20b": "internlm2_20b",
    "gemma2-9b": "gemma2_9b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llama3.2-1b": "llama3_2_1b",
    "musicgen-medium": "musicgen_medium",
    "chameleon-34b": "chameleon_34b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
        return mod.CONFIG
    if name in ("paper-gpt2-medium", "paper-bloom-560m"):
        mod = importlib.import_module("repro.configs.paper_models")
        return {"paper-gpt2-medium": mod.GPT2_MEDIUM,
                "paper-bloom-560m": mod.BLOOM_560M}[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_IDS)}")


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ModelConfig", "AttnConfig", "MoEConfig", "MambaConfig", "RWKVConfig",
    "LoRAConfig", "QuantConfig", "reduce_config", "get_config", "all_configs",
    "ARCH_IDS", "ALL_SHAPES", "SHAPES", "ShapeSuite", "TRAIN_4K",
    "PREFILL_32K", "DECODE_32K", "LONG_500K", "cell_supported",
]
