"""Model/config system for the Atleus reproduction framework.

Every assigned architecture (plus the paper's own models) is a frozen
``ModelConfig``. A config fully determines parameter shapes, the per-layer
block pattern (attention / mamba / rwkv), the FF type per layer (dense / MoE),
and the attention flavour per attention layer (full / sliding / alternating).

The same config drives:
  * parameter init (``repro.models.transformer.init_params``)
  * train / prefill / decode step construction
  * sharding rule derivation (``repro.dist.sharding``)
  * the analytical Atleus performance model (``repro.perfmodel``)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnConfig:
    """Attention behaviour. ``pattern`` cycles across *attention* layers:
    e.g. ("sliding",) = every attn layer sliding-window; ("sliding", "full")
    = gemma2-style local/global alternation."""

    pattern: Tuple[str, ...] = ("full",)
    window: Optional[int] = None          # sliding-window size (tokens)
    logit_softcap: Optional[float] = None  # gemma2 attn softcap (50.0)
    qk_norm: bool = False                 # chameleon-style query/key norm
    rope_theta: float = 10000.0

    def kind_for(self, attn_layer_idx: int) -> str:
        return self.pattern[attn_layer_idx % len(self.pattern)]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    period: int = 1          # MoE FF on layers with (idx % period == period-1)
    shared_expert: bool = False  # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # renormalize top-k probs to sum to 1


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default: ceil(d_model / 16)
    chunk: int = 256               # chunked-scan block length

    def rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank is not None else max(1, d_model // 16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64       # rank of the data-dependent decay LoRA (w)
    mix_lora: int = 32         # rank of the token-shift mix LoRA (x)
    gate_lora: int = 128


@dataclass(frozen=True)
class LoRAConfig:
    """Paper default: LoRA on W_Q and W_V with r=32 (Atleus SS V.A)."""

    rank: int = 32
    alpha: float = 32.0
    targets: Tuple[str, ...] = ("wq", "wv")
    dropout: float = 0.0


@dataclass(frozen=True)
class QuantConfig:
    """Crossbar-wise quantization (Atleus SS IV.D). ``MnFm``: n bits for the
    MHA (attention projection) weights, m bits for the FF weights. Block size
    128x128 == the ReRAM crossbar geometry == the MXU tile."""

    mha_bits: int = 16        # 16 == not quantized
    ff_bits: int = 16
    block: int = 128

    @property
    def tag(self) -> str:
        return f"M{self.mha_bits}F{self.ff_bits}"

    @property
    def enabled(self) -> bool:
        return self.mha_bits < 16 or self.ff_bits < 16


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str

    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    head_dim: Optional[int] = None        # explicit (gemma2/nemo differ from d/H)
    d_ff: int = 3072
    vocab_size: int = 32000

    # per-layer block kinds, cycled: ("attn",), ("rwkv",), jamba 1:7 etc.
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp: str = "gated_silu"               # gated_silu | gated_gelu | gelu
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: str = "tokens"              # tokens | embeddings (audio/vlm stub)
    max_seq_len: int = 131072
    emb_scale: bool = False               # gemma-style sqrt(d) embed scaling
    final_logit_softcap: Optional[float] = None

    lora: LoRAConfig = field(default_factory=LoRAConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)

    # ----- derived -----
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers, self.period)
        return self.n_layers // self.period

    def block_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % self.period]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return layer_idx % self.moe.period == self.moe.period - 1

    def attn_layer_indices(self) -> Tuple[int, ...]:
        return tuple(i for i, k in enumerate(self.layer_kinds()) if k == "attn")

    def attn_kind(self, layer_idx: int) -> str:
        """full|sliding for a given *global* layer index (must be attn)."""
        attn_idxs = self.attn_layer_indices()
        return self.attn.kind_for(attn_idxs.index(layer_idx))

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer does unbounded full attention (long_500k eligible)
        or the arch is SSM/hybrid (per the brief: run long_500k for
        SSM/hybrid/linear-attn; sliding-window is O(w))."""
        if self.family in ("ssm", "hybrid"):
            return True
        kinds = [self.attn.kind_for(i) for i in range(len(self.attn_layer_indices()))]
        if not kinds:
            return True
        if all(k == "sliding" for k in kinds):
            return True
        # local/global alternation (gemma2): not *pure* full attention
        return "sliding" in kinds

    # ----- parameter counting (for 6ND MODEL_FLOPS & memory budgeting) -----
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.hd
        total = 0
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif kind == "mamba":
                mc = self.mamba
                d_in = mc.expand * d
                r = mc.rank(d)
                total += d * 2 * d_in            # in_proj (x and z)
                total += d_in * (r + 2 * mc.d_state)  # x_proj
                total += r * d_in                # dt_proj
                total += mc.d_conv * d_in        # conv1d (depthwise)
                total += d_in * mc.d_state       # A_log
                total += d_in                    # D
                total += d_in * d                # out_proj
            elif kind == "rwkv":
                rc = self.rwkv
                total += 5 * d * d               # r,k,v,g(out-approx),o  time-mix
                total += d * rc.decay_lora * 2   # decay lora
                total += 2 * d * ff              # channel mix (k, v) rwkv ffn
                continue                         # rwkv has no separate FF block
            n_mat = 3 if self.mlp.startswith("gated") else 2
            if kind != "rwkv":
                if self.is_moe_layer(i):
                    total += self.moe.n_experts * n_mat * d * ff
                    if self.moe.shared_expert:
                        total += n_mat * d * ff
                    total += d * self.moe.n_experts  # router
                    if active_only:
                        total -= (self.moe.n_experts - self.moe.top_k) * n_mat * d * ff
                else:
                    total += n_mat * d * ff
        return total

    def lora_param_count(self) -> int:
        """Trainable LoRA params (the only trainable params in PEFT mode)."""
        r = self.lora.rank
        d = self.d_model
        dims = {"wq": (d, self.q_dim), "wk": (d, self.kv_dim),
                "wv": (d, self.kv_dim), "wo": (self.q_dim, d),
                "w1": (d, self.d_ff), "w2": (self.d_ff, d), "w3": (d, self.d_ff)}
        n_attn = len(self.attn_layer_indices())
        total = 0
        for t in self.lora.targets:
            din, dout = dims[t]
            n = n_attn if t in ("wq", "wk", "wv", "wo") else self.n_layers
            total += n * r * (din + dout)
        return total

    def validate(self) -> "ModelConfig":
        assert self.family in FAMILIES, self.family
        assert self.n_heads % self.n_kv_heads == 0
        assert self.n_layers % self.period == 0
        if self.moe is not None:
            assert any(self.is_moe_layer(i) for i in range(self.n_layers))
        if "mamba" in self.block_pattern:
            assert self.mamba is not None
        if "rwkv" in self.block_pattern:
            assert self.rwkv is not None
        for k in self.attn.pattern:
            assert k in ("full", "sliding"), k
        if "sliding" in self.attn.pattern:
            assert self.attn.window is not None
        return self


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig, *, n_periods: int = 2, d_model: int = 64,
                  n_heads: int = 4, d_ff: int = 128, vocab: int = 257,
                  window: int = 8) -> ModelConfig:
    """Shrink a config to smoke-test size while preserving its *structure*
    (block pattern, MoE period, attention alternation, norm/mlp kinds)."""
    kv = max(1, n_heads // max(1, cfg.n_heads // cfg.n_kv_heads))
    new = replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=cfg.period * n_periods,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d_model // n_heads,
        d_ff=d_ff,
        vocab_size=vocab,
        max_seq_len=4096,
        attn=replace(cfg.attn, window=(window if cfg.attn.window else None)),
        lora=replace(cfg.lora, rank=4, alpha=4.0),
    )
    if cfg.moe is not None:
        new = replace(new, moe=replace(cfg.moe, n_experts=4,
                                       top_k=min(cfg.moe.top_k, 2)))
    if cfg.mamba is not None:
        new = replace(new, mamba=replace(cfg.mamba, d_state=4, d_conv=4,
                                         dt_rank=8, chunk=16))
    if cfg.rwkv is not None:
        new = replace(new, rwkv=replace(cfg.rwkv, head_dim=16, decay_lora=8,
                                        mix_lora=4, gate_lora=8))
    return new.validate()
