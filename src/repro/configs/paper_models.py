"""The paper's own evaluation models (Atleus SS V.A): GPT-2 (Medium) and
BLOOM-560m shaped decoder configs, used by the paper-figure benchmarks
(compute breakdown, quantization perplexity, pipeline stage delays).
RoBERTa-Base / BERT-Large are encoder models; their kernel mix (Table II)
is identical, so the perfmodel evaluates them analytically by dims."""
from repro.configs.base import AttnConfig, ModelConfig

GPT2_MEDIUM = ModelConfig(
    name="paper-gpt2-medium",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=50257,
    block_pattern=("attn",),
    mlp="gelu",
    attn=AttnConfig(pattern=("full",)),
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=1024,
).validate()

BLOOM_560M = ModelConfig(
    name="paper-bloom-560m",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=250880,
    block_pattern=("attn",),
    mlp="gelu",
    attn=AttnConfig(pattern=("full",)),
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=2048,
).validate()

# Analytic-only dims for the encoder models (perfmodel paper figures).
PAPER_DIMS = {
    "roberta-base": dict(n_layers=12, d_model=768, n_max=512),
    "bert-large": dict(n_layers=24, d_model=1024, n_max=512),
    "gpt2-medium": dict(n_layers=24, d_model=1024, n_max=1024),
    "bloom-560m": dict(n_layers=24, d_model=1024, n_max=2048),
}
