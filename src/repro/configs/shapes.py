"""Assigned input-shape suites (one set, shared by all 10 LM-family archs).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers ``prefill_step``;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSuite("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSuite("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSuite("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSuite("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSuite, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def cell_supported(cfg: ModelConfig, shape: ShapeSuite) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if skipped.

    long_500k requires sub-quadratic attention; per the brief we skip it for
    pure full-attention archs and run it for SSM/hybrid/sliding-window archs
    (see DESIGN.md SS5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped(full-attn): long_500k requires sub-quadratic attention"
    return True, ""
