"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion over VQ image tokens (frontend STUB:
``input_specs()`` provides precomputed patch embeddings), qk-norm.
[arXiv:2405.09818; unverified]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    block_pattern=("attn",),
    mlp="gated_silu",
    attn=AttnConfig(pattern=("full",), rope_theta=1e4, qk_norm=True),
    norm="rmsnorm",
    frontend="embeddings",
    max_seq_len=4096,
).validate()
