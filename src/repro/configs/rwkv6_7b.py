"""rwkv6-7b (Finch) [ssm] — 32L d_model=4096 attention-free, d_ff=14336
vocab=65536; data-dependent decay time-mix + channel-mix.
[arXiv:2404.05892; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # wkv heads = d_model / rwkv.head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv",),
    mlp="gelu",           # unused by rwkv blocks (channel-mix is built in)
    attn=AttnConfig(pattern=("full",)),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=128),
    norm="layernorm",
    max_seq_len=1048576,
).validate()
