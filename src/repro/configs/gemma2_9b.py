"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local(sliding-4096)+global alternating attention, attention
logit softcap 50.0, final logit softcap 30.0, gelu-gated MLP, head_dim 256,
embedding scaling. [arXiv:2408.00118; hf]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    block_pattern=("attn",),
    mlp="gated_gelu",
    attn=AttnConfig(pattern=("sliding", "full"), window=4096,
                    logit_softcap=50.0, rope_theta=1e4),
    final_logit_softcap=30.0,
    norm="rmsnorm",
    tie_embeddings=True,
    emb_scale=True,
    max_seq_len=8192,
).validate()
