"""Roofline analysis over the dry-run artifacts (deliverable g).

Terms per (arch x shape x mesh), all per-device per-step:

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = wire_bytes / ICI_bw               (~50 GB/s/link; ring factors
                                                  already applied per op)

plus MODEL_FLOPS = 6*N(_active)*D cross-check and the dominant term.
HLO numbers come from the trip-count-aware parser (tpu-dtype corrected);
``python -m repro.roofline.analysis`` renders the full table.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import ALL_SHAPES, ARCH_IDS, SHAPES, cell_supported, get_config

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link
DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    quant: str
    compute_s: float
    memory_s: float
    collective_s: float
    peak_gib: float
    model_flops_ratio: float   # MODEL_FLOPS / (HLO_FLOPs * chips)
    memory_kern_s: float = 0.0   # with flash/wkv Pallas kernels (VMEM-resident)
    status: str = "ok"

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def dominant_kern(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_kern_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction_kern(self) -> float:
        b = max(self.compute_s, self.memory_kern_s, self.collective_s)
        return self.compute_s / b if b else 0.0

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute term / bound time: 1.0 == compute-bound at peak."""
        return self.compute_s / self.bound_time if self.bound_time else 0.0


def model_flops(arch: str, shape_name: str) -> float:
    """6*N(_active)*D for train (fwd+bwd); 2*N*D for prefill; 2*N*D_step for
    one decode token."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


def n_chips(mesh_tag: str) -> int:
    return 512 if "2x16x16" in mesh_tag else 256


def load_cell(arch: str, shape: str, mesh_tag: str, quant: str = "bf16",
              suffix: str = "") -> Optional[CellRoofline]:
    tag = "" if quant == "bf16" else f"__{quant}"
    path = DRYRUN_DIR / mesh_tag / f"{arch}__{shape}{tag}{suffix}.json"
    if not path.exists():
        return None
    rec = json.loads(path.read_text())
    if rec.get("status") != "ok":
        return CellRoofline(arch, shape, mesh_tag, quant, 0, 0, 0, 0, 0,
                            status=rec.get("status", "missing"))
    hc = rec["hlo_cost"]
    kern = rec.get("hlo_cost_kernelized", hc)
    chips = n_chips(mesh_tag)
    mf = model_flops(arch, shape)
    return CellRoofline(
        arch=arch, shape=shape, mesh=mesh_tag, quant=quant,
        compute_s=hc["flops"] / PEAK_FLOPS,
        memory_s=hc["bytes"] / HBM_BW,
        collective_s=hc["collective_bytes"] / ICI_BW,
        peak_gib=rec["memory"]["peak_bytes"] / (1 << 30),
        model_flops_ratio=mf / max(hc["flops"] * chips, 1.0),
        memory_kern_s=kern["bytes"] / HBM_BW,
    )


def full_table(mesh_tag: str = "pod16x16", quant: str = "bf16"
               ) -> List[CellRoofline]:
    out = []
    for arch in ARCH_IDS:
        for s in ALL_SHAPES:
            cell = load_cell(arch, s.name, mesh_tag, quant)
            if cell is not None:
                out.append(cell)
    return out


def render_markdown(cells: List[CellRoofline]) -> str:
    lines = [
        "| arch | shape | comp (ms) | mem (ms) | mem+kern (ms) | coll (ms) | "
        "bottleneck | peak GiB/dev | 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.status != "ok":
            lines.append(f"| {c.arch} | {c.shape} | - | - | - | - | - | - | - "
                         f"| {c.status} |")
            continue
        lines.append(
            f"| {c.arch} | {c.shape} | {c.compute_s*1e3:.1f} | "
            f"{c.memory_s*1e3:.1f} | {c.memory_kern_s*1e3:.1f} | "
            f"{c.collective_s*1e3:.2f} | "
            f"**{c.dominant_kern}** | {c.peak_gib:.1f} | "
            f"{c.model_flops_ratio:.2f} | "
            f"{c.roofline_fraction_kern:.2f} |")
    return "\n".join(lines)


def main():
    for mesh_tag in ("pod16x16", "pod2x16x16"):
        cells = full_table(mesh_tag)
        if not cells:
            continue
        print(f"\n## Roofline — {mesh_tag}\n")
        print(render_markdown(cells))


if __name__ == "__main__":
    main()
