"""HLO text parser for exact roofline accounting.

``compiled.cost_analysis()`` visits while bodies ONCE, so scanned-layer
models under-report by the trip count. This parser rebuilds per-device
cost from the optimized (post-SPMD-partitioning) HLO text:

  * flops: dot/convolution ops, 2*|result|*K from explicit contracting dims;
  * bytes: operand+result sizes per op (fusion internals excluded — fusion
    boundary traffic only, matching XLA's own bytes-accessed semantics);
  * collectives: per-op wire bytes with ring-algorithm factors and replica
    group sizes;
  * control flow: while bodies multiplied by ``known_trip_count``;
    conditionals take the max branch; calls/fusions walked once.

Shapes in the partitioned module are per-device, so all totals are
per-device numbers.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SIMPLE_SHAPE_RE = re.compile(r"^\w+\[[\d,]*\](?:\{[^}]*\})?")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\":{ ]*n[\\": ]+(\d+)')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_METADATA_RE = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
# ops whose operand/result bytes are not real traffic
SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "while", "conditional", "call", "after-all", "iota",
    "partition-id", "replica-id", "custom-call", "rng-bit-generator",
}
CONTROL = {"while", "conditional", "call", "fusion"}


def shape_bytes(shape_text: str) -> int:
    """Total bytes for a (possibly tuple) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(shape_text: str) -> int:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class OpInfo:
    name: str
    shape: str
    opcode: str
    rest: str  # everything after the open paren (operands + attrs)
    scope: str = ""  # metadata op_name (jax named_scope path)


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)


class HloModule:
    """``tpu_dtypes=True`` counts f32 buffers at 2 bytes/element: XLA:CPU's
    float-normalization pass upcasts all bf16 compute to f32, which a TPU
    lowering would keep in bf16 — the corrected numbers are the roofline
    inputs (raw numbers are kept alongside for cross-checking)."""

    def __init__(self, text: str, tpu_dtypes: bool = False,
                 fused_regions: Tuple[str, ...] = ()):
        """``fused_regions``: named_scope tags whose interior ops have a
        Pallas kernel equivalent that keeps them VMEM-resident (e.g.
        "flash_fused", "wkv_fused") — their FLOPs count, their HBM bytes
        don't (kernel boundary traffic is counted at the producers/consumers
        outside the scope)."""
        self.comps: Dict[str, List[OpInfo]] = {}
        self.entry: Optional[str] = None
        self.shapes: Dict[str, str] = {}
        self.dtype_bytes = dict(DTYPE_BYTES)
        self.fused_regions = tuple(fused_regions)
        if tpu_dtypes:
            self.dtype_bytes["f32"] = 2
        self._parse(text)
        self._cost_cache: Dict[str, CompCost] = {}
        self.warnings: List[str] = []

    def _in_fused_region(self, op: OpInfo) -> bool:
        return any(tag in op.scope for tag in self.fused_regions)

    def _shape_bytes(self, shape_text: str) -> int:
        total = 0
        for dt, dims in _SHAPE_RE.findall(shape_text):
            if dt not in self.dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * self.dtype_bytes[dt]
        return total

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            mc = _COMP_RE.match(line)
            if mc and line.endswith("{"):
                cur = mc.group(1)
                self.comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            parsed = self._parse_op_line(line)
            if parsed is None:
                continue
            name, shape, opcode, rest = parsed
            # capture then strip metadata (op_name carries named_scope paths)
            ms = _METADATA_RE.search(rest)
            scope = ms.group(1) if ms else ""
            rest = re.sub(r"metadata=\{[^}]*\}", "", rest)
            op = OpInfo(name, shape, opcode, rest, scope)
            self.comps[cur].append(op)
            self.shapes[name] = shape

    @staticmethod
    def _parse_op_line(line: str):
        mo = _LHS_RE.match(line)
        if not mo:
            return None
        name, rhs = mo.group(1), mo.group(2).strip()
        if rhs.startswith("("):   # tuple shape: balanced-paren scan
            depth = 0
            end = 0
            for i, c in enumerate(rhs):
                if c == "(":
                    depth += 1
                elif c == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            shape = rhs[:end]
            rest = rhs[end:]
        else:
            ms = _SIMPLE_SHAPE_RE.match(rhs)
            if not ms:
                return None
            shape = ms.group(0)
            rest = rhs[ms.end():]
        mo2 = _OPCODE_RE.match(rest)
        if not mo2:
            return None
        return name, shape, mo2.group(1), mo2.group(2)

    # ------------------------------------------------------------------
    def _operand_names(self, op: OpInfo) -> List[str]:
        # operands are %names at the top level before the closing paren
        head = op.rest.split("),", 1)[0]
        return re.findall(r"%([\w.\-]+)", head)

    def _operand_bytes(self, op: OpInfo) -> int:
        return sum(self._shape_bytes(self.shapes.get(n, "")) for n in
                   self._operand_names(op))

    def _dot_flops(self, op: OpInfo) -> float:
        ops = self._operand_names(op)
        if not ops:
            return 0.0
        lhs_shape = self.shapes.get(ops[0], "")
        m = _SHAPE_RE.search(lhs_shape)
        if not m:
            return 0.0
        lhs_dims = [int(d) for d in m.group(2).split(",") if d]
        mc = re.search(r"lhs_contracting_dims=\{([^}]*)\}", op.rest)
        k = 1
        if mc:
            for idx in mc.group(1).split(","):
                if idx.strip():
                    k *= lhs_dims[int(idx)]
        return 2.0 * shape_elems(op.shape) * k

    def _conv_flops(self, op: OpInfo) -> float:
        ops = self._operand_names(op)
        if len(ops) < 2:
            return 0.0
        kshape = self.shapes.get(ops[1], "")
        m = _SHAPE_RE.search(kshape)
        if not m:
            return 0.0
        kdims = [int(d) for d in m.group(2).split(",") if d]
        kelems = 1
        for d in kdims:
            kelems *= d
        # heuristic: per-output-element work = |kernel| / (feature dim);
        # exact for the depthwise convs used here (mamba: (K, C) kernels)
        feat = max(kdims) if kdims else 1
        return 2.0 * shape_elems(op.shape) * kelems / max(feat, 1)

    def _collective_bytes(self, op: OpInfo) -> Tuple[float, int]:
        """(wire bytes per device, group size)."""
        g = 1
        mi = _GROUPS_ITOA_RE.search(op.rest)
        if mi:
            g = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(op.rest)
            if ml:
                g = len([x for x in ml.group(1).split(",") if x.strip() != ""])
        kind = next(c for c in COLLECTIVES if op.opcode.startswith(c))
        res = self._shape_bytes(op.shape)
        opnd = self._operand_bytes(op)
        ring = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            wire = 2.0 * res * ring
        elif kind == "all-gather":
            wire = res * ring
        elif kind == "reduce-scatter":
            wire = opnd * ring
        elif kind in ("all-to-all", "ragged-all-to-all"):
            wire = opnd * ring
        else:  # collective-permute
            wire = opnd
        return wire, g

    # ------------------------------------------------------------------
    def _fusion_bytes(self, op: OpInfo, comp_name: Optional[str]) -> float:
        """Boundary traffic of a fusion, slice-aware: a fused dynamic-slice
        reads only its slice of a big operand (e.g. the stacked xs of a
        scanned loop), not the whole buffer."""
        total = 0.0
        root_is_dus = False
        sliced_params = {}
        if comp_name and comp_name in self.comps:
            params = {}
            for iop in self.comps[comp_name]:
                if iop.opcode == "parameter":
                    m = re.match(r"\s*(\d+)", iop.rest)
                    if m:
                        params[iop.name] = int(m.group(1))
                elif iop.opcode in ("dynamic-slice", "gather"):
                    ons = self._operand_names(iop)
                    if ons and ons[0] in params:
                        sliced_params[params[ons[0]]] = self._shape_bytes(iop.shape)
                elif iop.opcode == "dynamic-update-slice":
                    ons = self._operand_names(iop)
                    if ons and ons[0] in params:
                        upd = (self._shape_bytes(self.shapes.get(ons[1], ""))
                               if len(ons) > 1 else 0)
                        sliced_params[params[ons[0]]] = upd
                        root_is_dus = True
        for i, name in enumerate(self._operand_names(op)):
            if i in sliced_params:
                total += sliced_params[i]
            else:
                total += self._shape_bytes(self.shapes.get(name, ""))
        if root_is_dus and len(sliced_params) == 1:
            total += next(iter(sliced_params.values()))
        else:
            total += self._shape_bytes(op.shape)
        return total

    def comp_cost(self, name: str, fused: bool = False) -> CompCost:
        key = f"{name}|{fused}"
        if key in self._cost_cache:
            return self._cost_cache[key]
        cost = CompCost()
        self._cost_cache[key] = cost  # guard recursion
        for op in self.comps.get(name, []):
            oc = op.opcode
            in_kernel = self.fused_regions and self._in_fused_region(op)
            if oc == "dot":
                cost.flops += self._dot_flops(op)
                if fused:
                    self.warnings.append(f"dot inside fusion comp {name}")
            elif oc == "convolution":
                cost.flops += self._conv_flops(op)
            if any(oc.startswith(c) for c in COLLECTIVES) and not oc.endswith("-done"):
                wire, _ = self._collective_bytes(op)
                cost.coll_bytes += wire
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) + wire
            if oc == "while":
                body = _COND_BODY_RE.search(op.rest)
                mt = _TRIP_RE.search(op.rest)
                trips = int(mt.group(1)) if mt else 1
                if not mt:
                    self.warnings.append(f"while without trip count in {name}")
                if body:
                    if body.group(1) not in self.comps:
                        self.warnings.append(f"missing while body {body.group(1)}")
                    sub = self.comp_cost(body.group(1))
                    cost.flops += trips * sub.flops
                    cost.bytes += trips * sub.bytes
                    cost.coll_bytes += trips * sub.coll_bytes
                    for k, v in sub.coll_by_kind.items():
                        cost.coll_by_kind[k] = cost.coll_by_kind.get(k, 0.0) + trips * v
                continue
            if oc == "conditional":
                mb = _BRANCH_RE.search(op.rest)
                if mb:
                    subs = [self.comp_cost(b.strip().lstrip("%"))
                            for b in mb.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s.flops + s.bytes)
                        cost.flops += best.flops
                        cost.bytes += best.bytes
                        cost.coll_bytes += best.coll_bytes
                continue
            if oc in ("call", "fusion"):
                mc = _CALL_RE.search(op.rest)
                if mc:
                    sub = self.comp_cost(mc.group(1), fused=(oc == "fusion"))
                    cost.flops += sub.flops
                    if oc == "call":
                        cost.bytes += sub.bytes
                        cost.coll_bytes += sub.coll_bytes
                if oc == "fusion" and not in_kernel:
                    cost.bytes += self._fusion_bytes(op, mc.group(1) if mc else None)
                continue
            if oc in SKIP_BYTES:
                continue
            if in_kernel:          # VMEM-resident inside the Pallas kernel
                continue
            # in-place / windowed ops: count touched bytes, not full buffers
            if oc == "dynamic-update-slice":
                ops_n = self._operand_names(op)
                upd = self._shape_bytes(self.shapes.get(ops_n[1], "")) if len(ops_n) > 1 else 0
                cost.bytes += 2 * upd
                continue
            if oc == "dynamic-slice":
                cost.bytes += 2 * self._shape_bytes(op.shape)
                continue
            if oc == "gather":
                cost.bytes += 2 * self._shape_bytes(op.shape)
                continue
            if oc == "scatter":
                ops_n = self._operand_names(op)
                upd = (self._shape_bytes(self.shapes.get(ops_n[2], ""))
                       if len(ops_n) > 2 else self._shape_bytes(op.shape))
                cost.bytes += 3 * upd
                continue
            if oc == "broadcast":   # fuses into consumers on TPU
                continue
            cost.bytes += self._shape_bytes(op.shape) + self._operand_bytes(op)
        self._cost_cache[key] = cost
        return cost

    def entry_cost(self) -> CompCost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo_text(text: str) -> CompCost:
    return HloModule(text).entry_cost()
