"""RWKV6 "Finch" block (rwkv6-7b): attention-free time-mix with
data-dependent decay + channel-mix.

Paper-technique mapping (DESIGN.md SS5): all projections (r/k/v/g/o,
channel-mix) are STATIC-engine frozen weights and crossbar-quantize fine;
the wkv recurrence (state S in R^{H x N x N} with per-token decay w_t) is a
dynamic recurrence -> DYNAMIC engine. The recurrence runs as a sequential
``lax.scan`` over time, vectorized over (B, H, N, N); the TPU Pallas kernel
(`repro.kernels.rwkv6_wkv`) keeps the state VMEM-resident (the
output-stationary dataflow analogue).

Recurrence (official Finch form), per head, N = head_dim:
    y_t     = r_t · (S_t + u ⊙ (k_t ⊗ v_t))
    S_{t+1} = diag(w_t) S_t + k_t ⊗ v_t
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero
from repro.core.noise import NoiseConfig
from repro.models import layers

Array = jax.Array

MIX_NAMES = ("r", "w", "k", "v", "g")

# Per-slot decode-state leaves: token-shift buffers hold the previous
# token's activations and the wkv matrix accumulates over the whole
# stream, all indexed by slot row (batch dim). The serving
# ``SlotStateArena`` snapshots / restores / zeroes them by slot id — a
# paged-KV cursor rewind cannot rewind them.
SLOT_STATE_LEAVES = ("shift_t", "shift_c", "wkv")


def init_rwkv(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Array]:
    rc = cfg.rwkv
    d = cfg.d_model
    H = d // rc.head_dim
    ks = jax.random.split(key, 16)
    ratio = jnp.arange(d, dtype=jnp.float32) / d
    p = {
        "ln1": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "ln2": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "time_mix": {
            "mu": jnp.stack([1.0 - ratio ** (0.3 + 0.1 * i) for i in range(5)]).astype(dtype),
            "mu_x": (1.0 - ratio ** 0.3).astype(dtype),
            "w_mix_a": layers.dense_init(ks[0], (d, 5 * rc.mix_lora), dtype),
            "w_mix_b": (0.02 * jax.random.normal(ks[1], (5, rc.mix_lora, d))).astype(dtype),
            "w_base": (-6.0 + 5.0 * ratio).astype(jnp.float32),
            "w_lora_a": layers.dense_init(ks[2], (d, rc.decay_lora), dtype),
            "w_lora_b": (0.02 * jax.random.normal(ks[3], (rc.decay_lora, d))).astype(dtype),
            "u": (0.5 * jnp.ones((H, rc.head_dim))).astype(jnp.float32),
            "r_proj": layers.dense_init(ks[4], (d, d), dtype),
            "k_proj": layers.dense_init(ks[5], (d, d), dtype),
            "v_proj": layers.dense_init(ks[6], (d, d), dtype),
            "g_proj": layers.dense_init(ks[7], (d, d), dtype),
            "o_proj": layers.dense_init(ks[8], (d, d), dtype),
            "ln_x": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        },
        "channel_mix": {
            "mu_k": (1.0 - ratio ** 0.3).astype(dtype),
            "mu_r": (1.0 - ratio ** 0.3).astype(dtype),
            "ck_proj": layers.dense_init(ks[9], (d, cfg.d_ff), dtype),
            "cv_proj": layers.dense_init(ks[10], (cfg.d_ff, d), dtype, fan_in=cfg.d_ff),
            "cr_proj": layers.dense_init(ks[11], (d, d), dtype),
        },
    }
    return p


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """xx_t = x_{t-1}; first step uses ``prev`` (decode cache) or zeros."""
    B, T, d = x.shape
    first = jnp.zeros((B, 1, d), x.dtype) if prev is None else prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1, :]], axis=1) if T > 1 else first


def wkv_scan(r: Array, k: Array, v: Array, w: Array, u: Array, s0: Array,
             chunk: int = 64, sharder=None) -> Tuple[Array, Array]:
    """Sequential wkv recurrence, chunk-checkpointed.

    r/k/v/w (B,T,H,N) f32; u (H,N); s0 (B,H,N,N). Returns y (B,T,H,N),
    s_final. The scan over time is grouped into chunks whose bodies are
    ``jax.checkpoint``ed: the backward pass saves only chunk-boundary
    states (T/chunk x B*H*N*N) and recomputes the per-step states within
    one chunk at a time — without this, autodiff saves the full (T, B, H,
    N, N) state history (16 GiB/device at T=4096 for rwkv6-7b)."""
    hetero.record_nonlinear(r.size)
    hetero._record(hetero.DYNAMIC, 4.0 * r.shape[0] * r.shape[1] *
                   r.shape[2] * r.shape[3] ** 2)
    B, T, H, N = r.shape
    sh = sharder if sharder is not None else (lambda x, n: x)
    s0 = sh(s0, "wkv_state")

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                      # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,N,N)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    if T == 1:
        s_fin, y = step(s0, (r[:, 0], k[:, 0], v[:, 0], w[:, 0]))
        return y[:, None], s_fin

    L = min(chunk, T)
    pad = (-T) % L
    def to_chunks(x):
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nc = xp.shape[1] // L
        return xp.reshape(B, nc, L, H, N).transpose(1, 2, 0, 3, 4)  # (nc,L,B,H,N)

    rc, kc, vc, wc = (sh(to_chunks(x), "wkv_chunks") for x in (r, k, v, w))
    # padded steps: w=1, k=0 -> state unchanged
    if pad:
        valid = (jnp.arange(rc.shape[0] * L) < T).reshape(rc.shape[0], L)
        m = valid[:, :, None, None, None]
        kc = jnp.where(m, kc, 0.0)
        wc = jnp.where(m, wc, 1.0)

    @jax.checkpoint
    def chunk_fn(s, rkvw_c):
        with jax.named_scope("wkv_fused"):
            s, ys = jax.lax.scan(step, sh(s, "wkv_state"), rkvw_c)
        return sh(s, "wkv_state"), ys

    s_fin, ys = jax.lax.scan(chunk_fn, s0, (rc, kc, vc, wc))  # ys (nc,L,B,H,N)
    y = ys.transpose(2, 0, 1, 3, 4).reshape(B, -1, H, N)[:, :T]
    return y, s_fin


def apply_rwkv_block(
    cfg: ModelConfig, p: Dict[str, Array], x: Array, *,
    cache: Optional[Dict[str, Array]] = None,
    lora: Optional[Dict] = None, adapter_idx=None,
    noise: Optional[NoiseConfig] = None, rng: Optional[Array] = None,
    impl: str = "auto", sharder=None,
    chunk_lens: Optional[Array] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """Full RWKV6 block: x + time_mix(ln1(x)); then + channel_mix(ln2(.)).

    cache: {shift_t (B,d), shift_c (B,d), wkv (B,H,N,N) f32}.

    ``chunk_lens`` (B,) marks ragged decode chunks: padded steps run the
    wkv recurrence with k=0, w=1 (state unchanged) and the emitted shift
    states come from each row's last *valid* token."""
    from repro.core.lora import lora_delta, lora_scale

    rc = cfg.rwkv
    tm = p["time_mix"]
    B, T, d = x.shape
    H, N = d // rc.head_dim, rc.head_dim
    scale = lora_scale(cfg)

    # ---------------- time mix ----------------
    xn = layers.layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"], cfg.norm_eps)
    xx = _token_shift(xn, cache["shift_t"] if cache is not None else None)
    diff = xx - xn
    # dynamic token-shift mixing (the "ddd" lora)
    xmix = xn + diff * tm["mu_x"]
    ddd = jnp.tanh(hetero.static_matmul(xmix, tm["w_mix_a"]))
    ddd = ddd.reshape(B, T, 5, rc.mix_lora)
    dyn = hetero.dynamic_einsum("btfr,frd->btfd", ddd,
                                tm["w_mix_b"].astype(x.dtype))
    mixed = {}
    for i, name in enumerate(MIX_NAMES):
        mixed[name] = xn + diff * (tm["mu"][i] + dyn[:, :, i, :])

    def proj(name, target):
        y = hetero.static_matmul(mixed[name], tm[f"{name}_proj"],
                                 noise=noise, rng=rng)
        if lora is not None and target in lora:
            y = y + lora_delta(mixed[name], lora[target], scale, adapter_idx)
        return y

    r = proj("r", "wq").reshape(B, T, H, N).astype(jnp.float32)
    k = proj("k", "wk").reshape(B, T, H, N).astype(jnp.float32)
    v = proj("v", "wv").reshape(B, T, H, N).astype(jnp.float32)
    g = jax.nn.silu(hetero.static_matmul(mixed["g"], tm["g_proj"],
                                         noise=noise, rng=rng))

    # data-dependent decay w_t in (0, 1)
    w_raw = tm["w_base"] + hetero.dynamic_matmul(
        jnp.tanh(hetero.static_matmul(mixed["w"], tm["w_lora_a"])),
        tm["w_lora_b"].astype(x.dtype)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(B, T, H, N)
    hetero.record_nonlinear(w.size * 2)

    if chunk_lens is not None:
        # padded steps: k=0, w=1 -> wkv state passes through unchanged
        valid = (jnp.arange(T)[None, :] < chunk_lens[:, None])[..., None, None]
        k = jnp.where(valid, k, 0.0)
        w = jnp.where(valid, w, 1.0)

    s0 = (cache["wkv"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, H, N, N), jnp.float32))
    if impl == "pallas":
        from repro.kernels.rwkv6_wkv import ops as wkv_ops
        y, s_fin = wkv_ops.rwkv6_wkv(r, k, v, w, tm["u"], s0)
    else:
        y, s_fin = wkv_scan(r, k, v, w, tm["u"], s0, sharder=sharder)

    # per-head groupnorm, gate, output proj
    yf = y.reshape(B, T, H, N)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yf = yf.reshape(B, T, d) * p["time_mix"]["ln_x"]["scale"] + tm["ln_x"]["bias"]
    hetero.record_nonlinear(yf.size)
    att = hetero.static_matmul((yf.astype(x.dtype) * g), tm["o_proj"],
                               noise=noise, rng=rng)
    if lora is not None and "wo" in lora:
        att = att + lora_delta(yf.astype(x.dtype) * g, lora["wo"], scale,
                               adapter_idx)
    x = x + att

    # ---------------- channel mix ----------------
    cm = p["channel_mix"]
    xn2 = layers.layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"], cfg.norm_eps)
    xx2 = _token_shift(xn2, cache["shift_c"] if cache is not None else None)
    xk = xn2 + (xx2 - xn2) * cm["mu_k"]
    xr = xn2 + (xx2 - xn2) * cm["mu_r"]
    kf = hetero.static_matmul(xk, cm["ck_proj"], noise=noise, rng=rng)
    kf = jnp.square(jax.nn.relu(kf))
    hetero.record_nonlinear(kf.size)
    vf = hetero.static_matmul(kf, cm["cv_proj"], noise=noise, rng=rng)
    rg = jax.nn.sigmoid(hetero.static_matmul(xr, cm["cr_proj"],
                                             noise=noise, rng=rng))
    x = x + rg * vf

    new_cache = None
    if cache is not None:
        if chunk_lens is None:
            shift_t, shift_c = xn[:, -1, :], xn2[:, -1, :]
        else:
            last = jnp.clip(chunk_lens - 1, 0, T - 1)[:, None, None]
            shift_t = jnp.take_along_axis(xn, last, axis=1)[:, 0]
            shift_c = jnp.take_along_axis(xn2, last, axis=1)[:, 0]
            # rows with an empty chunk keep their incoming shift state
            alive = (chunk_lens > 0)[:, None]
            shift_t = jnp.where(alive, shift_t, cache["shift_t"].astype(shift_t.dtype))
            shift_c = jnp.where(alive, shift_c, cache["shift_c"].astype(shift_c.dtype))
        new_cache = {
            "shift_t": shift_t,
            "shift_c": shift_c,
            "wkv": s_fin.astype(cache["wkv"].dtype),
        }
    return x, new_cache
