"""Mixture-of-Experts FF with a unified expert-parallel "slot" layout.

Experts are laid out over ``n_slots = max(n_experts, moe_parallel)`` slots so
any expert count maps onto any mesh width:

  * E >= mesh (llama4/jamba, 16e on model=16): 1 expert per slot — pure EP.
  * E <  mesh (mixtral, 8e on model=16): each expert's FF dim is *split*
    across ``tpe = slots/E`` consecutive slots (EP x expert-TP hybrid). A
    routed token is dispatched to all ``tpe`` slots of its expert; the w2
    halves sum in the combine einsum, reproducing the full expert exactly
    with no weight duplication and unchanged total FLOPs.

Two dispatch modes share one combine and one expert-buffer layout:

  * ``dispatch="capacity"`` (training default): capacity-bucketed one-hot
    einsums (Switch/GLaM style — fully GSPMD-partitionable; the expert
    buffers carry the EP all-to-all). Tokens over capacity are dropped
    (residual passes through). This is the GSPMD-friendly shape for large
    fixed-length training batches, where the C ~ T*k*cf/slots buffer is
    far smaller than the T-row drop-free buffer.
  * ``dispatch="dropless"`` (forced by the serving engines): the expert
    buffer holds ``C = T`` rows per slot — the exact upper bound of any
    per-slot token count, since a token's k experts are distinct and
    expand to disjoint slot ranges — and tokens scatter to their
    rank-within-slot segment offset (prefix-sum of the per-slot counts)
    instead of contracting against a capacity one-hot. No token can ever
    drop, so routing is invariant to how serving batches a stream into
    prefill chunks / decode rows / spec-verify tails; serving greedy
    tokens cannot depend on chunking, preemption, or batch composition.

Both modes report ``aux["dropped_tokens"]`` (always 0 under dropless) and
agree to float tolerance whenever capacity is sufficient.

Routing math runs in f32; the router itself is a frozen base weight in PEFT
mode (STATIC engine) but is excluded from crossbar quantization (tiny).

The same slot axis is what the serving engine's tensor parallelism
(``ParallelConfig(tp=N)``) shards: expert weights partition across the
``model`` mesh axis via the ``moe_*`` rules in ``dist/sharding.py``, so a
paged decode step at tp=N runs EP over the slot dimension with routing
decisions (f32, replicated) identical to the single-device engine.
"""
from __future__ import annotations

import math
from fractions import Fraction
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero, quant
from repro.core.noise import NoiseConfig
from repro.models import layers

Array = jax.Array


def slot_layout(cfg: ModelConfig, moe_parallel: int) -> Tuple[int, int]:
    E = cfg.moe.n_experts
    slots = max(E, moe_parallel)
    assert slots % E == 0, (slots, E)
    tpe = slots // E
    assert cfg.d_ff % tpe == 0
    return slots, tpe


def init_moe(cfg: ModelConfig, key: Array, dtype, moe_parallel: int = 1
             ) -> Dict[str, Array]:
    d, ff = cfg.d_model, cfg.d_ff
    slots, tpe = slot_layout(cfg, moe_parallel)
    ffp = ff // tpe
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (d, cfg.moe.n_experts), jnp.float32),
        "w1": layers.dense_init(ks[1], (slots, d, ffp), dtype),
        "w2": layers.dense_init(ks[2], (slots, ffp, d), dtype, fan_in=ff),
    }
    if cfg.mlp.startswith("gated"):
        p["w3"] = layers.dense_init(ks[3], (slots, d, ffp), dtype)
    if cfg.moe.shared_expert:
        p["shared"] = layers.init_mlp(cfg, ks[4], dtype)
    return p


def live_slots(w) -> int:
    """Leading (slots) dim of an expert weight; QuantizedTensor meta keeps
    the pre-scan-slice orig_shape, so read the live codes array."""
    return w.codes.shape[0] if quant.is_quantized(w) else w.shape[0]


def _capacity(cfg: ModelConfig, tokens_per_group: int, k_slots: int,
              slots: int, capacity_factor: Optional[float]) -> int:
    cf = capacity_factor if capacity_factor is not None else cfg.moe.capacity_factor
    # exact integer ceil: int(x + 0.999) under-allocates whenever the true
    # quotient's fractional part lands in (0, 0.001) — e.g. 4001 tokens
    # over 2000 slots at cf=1.0 needs 3 rows, not 2. Fraction(cf) is the
    # float's exact value, so the ceil is integer math with no rounding.
    q = Fraction(tokens_per_group * k_slots) * Fraction(cf) / slots
    return max(1, math.ceil(q))


def apply_moe(cfg: ModelConfig, p: Dict[str, Array], x: Array, *,
              noise: Optional[NoiseConfig] = None, rng: Optional[Array] = None,
              capacity_factor: Optional[float] = None, sharder=None,
              group_size: Optional[int] = None,
              token_mask: Optional[Array] = None,
              dispatch: str = "capacity"
              ) -> Tuple[Array, Dict[str, Array]]:
    """x (B, T, d) -> (y (B, T, d), aux losses + drop accounting).

    ``token_mask`` (B, T) marks rows/cols that are real tokens; masked
    (padded) tokens neither claim expert capacity nor rank positions —
    required by ragged chunked prefill, where a chunk's padded tail must
    not displace real tokens from their expert slots.

    ``dispatch`` selects how tokens reach their expert buffers:

      * ``"capacity"`` — fixed per-group capacity bucket ``C`` from
        ``_capacity``; tokens ranked past ``C`` in a slot are dropped
        (residual passes through). The GSPMD-friendly training shape.
      * ``"dropless"`` — the buffer holds ``C = T`` rows per slot (the
        exact per-slot maximum) and tokens scatter to their
        rank-within-slot offset, so no token can ever drop. Serving
        forces this mode: it makes routing — and therefore greedy
        tokens — invariant to prefill chunking, preemption/resume, and
        speculative verify widths, and it subsumes the per-row exact
        routing that spec-decode verification used to special-case.

    ``aux["dropped_tokens"]`` counts (token, expert) assignments dropped
    by capacity (identically 0 under dropless).

    Tokens are routed in groups of ``group_size`` (capacity is per-group):
    smaller groups shrink the dispatch/combine one-hot einsums linearly
    (their FLOPs are tokens*slots*C*d with C ∝ group size) and — when the
    group size equals the per-shard sequence chunk — keep the dispatch
    contraction local to the shard, so the only collective left is the EP
    all-to-all on the expert buffers."""
    if dispatch not in ("capacity", "dropless"):
        raise ValueError(f"unknown MoE dispatch mode {dispatch!r} "
                         "(expected 'capacity' or 'dropless')")
    B0, T0, d = x.shape
    gs = group_size or T0
    if gs < T0 and T0 % gs == 0:
        x = x.reshape(B0 * (T0 // gs), gs, d)
        if token_mask is not None:
            token_mask = token_mask.reshape(B0 * (T0 // gs), gs)
    if sharder is not None:
        x = sharder(x, "moe_tokens")
    B, T, d = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    slots = live_slots(p["w1"])
    tpe = slots // E
    k_slots = k * tpe

    # ---- routing (f32, frozen router) ----
    logits = hetero.static_matmul(x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (B, T, E)
    gate, eidx = jax.lax.top_k(probs, k)                      # (B, T, k)
    if cfg.moe.router_norm_topk:
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch eq. 4) — reported even when frozen
    me = jnp.mean(jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "router_z": jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)}

    # ---- expand experts to slots ----
    sidx = (eidx[..., None] * tpe + jnp.arange(tpe)).reshape(B, T, k_slots)
    sgate = jnp.repeat(gate, tpe, axis=-1)                    # (B, T, k_slots)

    oh = jax.nn.one_hot(sidx, slots, dtype=jnp.float32)       # (B, T, K, slots)
    if token_mask is not None:
        m = token_mask.astype(jnp.float32)
        oh = oh * m[:, :, None, None]       # pads claim no rank/capacity
        sgate = sgate * m[:, :, None]
    pos = jnp.cumsum(oh.reshape(B, T * k_slots, slots), axis=1)
    pos = pos.reshape(B, T, k_slots, slots) - oh              # rank within slot
    pos_a = jnp.sum(pos * oh, axis=-1).astype(jnp.int32)      # (B, T, K)
    routed = sgate > 0

    if dispatch == "dropless":
        # every token holds <= 1 assignment per slot (its k experts are
        # distinct and expand to disjoint slot ranges), so rank < T: a
        # C = T buffer fits every routed token and drops are impossible.
        C = T
        aux["dropped_tokens"] = jnp.zeros((), jnp.float32)
        flat = sidx * C + pos_a                               # (B, T, K)
        src = x[:, :, None, :] * routed[..., None].astype(x.dtype)
        xin = jnp.zeros((B, slots * C, d), x.dtype)
        xin = xin.at[jnp.arange(B)[:, None, None], flat].add(src,
                                                             mode="drop")
        xin = xin.reshape(B, slots, C, d).transpose(1, 0, 2, 3)  # sbcd
    else:
        C = _capacity(cfg, T, k_slots, slots, capacity_factor)
        in_cap = (pos_a < C) & routed
        aux["dropped_tokens"] = (jnp.sum(routed & ~in_cap,
                                         dtype=jnp.float32) / tpe)
        # combine[b,t,s,c] = sum_k gate * 1[slot==s] * 1[rank==c]
        combine = jnp.einsum(
            "btks,btkc->btsc", oh * (sgate * in_cap)[..., None],
            jax.nn.one_hot(pos_a, C, dtype=jnp.float32))
        disp = (combine > 0).astype(x.dtype)
        if sharder is not None:
            disp = sharder(disp, "moe_dispatch")
        xin = hetero.dynamic_einsum("btsc,btd->sbcd", disp, x)

    # ---- expert compute -> combine (shared by both dispatch modes) ----
    if sharder is not None:
        xin = sharder(xin, "moe_buffer")                      # EP all-to-all
    h = hetero.static_einsum("sbcd,sdf->sbcf", xin, p["w1"], noise=noise, rng=rng)
    if cfg.mlp.startswith("gated"):
        g = hetero.static_einsum("sbcd,sdf->sbcf", xin, p["w3"], noise=noise,
                                 rng=rng)
        h = layers._act(cfg, h) * g
    else:
        h = layers._act(cfg, h)
    out_e = hetero.static_einsum("sbcf,sfd->sbcd", h, p["w2"], noise=noise,
                                 rng=rng)
    if sharder is not None:
        out_e = sharder(out_e, "moe_buffer")
    if dispatch == "dropless":
        # gather each assignment's expert output back by its (slot, rank)
        # segment offset; gate-0 / masked assignments carry zero weight
        o = out_e.transpose(1, 0, 2, 3).reshape(B, slots * C, d)
        sel = jnp.take_along_axis(
            o, flat.reshape(B, T * k_slots)[:, :, None], axis=1)
        w = jnp.where(routed, sgate, 0.0).astype(x.dtype)
        y = jnp.sum(sel.reshape(B, T, k_slots, d) * w[..., None], axis=2)
    else:
        y = hetero.dynamic_einsum("btsc,sbcd->btd",
                                  combine.astype(x.dtype), out_e)
    if sharder is not None:
        y = sharder(y, "moe_tokens")

    if cfg.moe.shared_expert:
        y = y + layers.apply_mlp(cfg, p["shared"], x, noise=noise, rng=rng)
    y = y.astype(x.dtype)
    if (B, T) != (B0, T0):
        y = y.reshape(B0, T0, d)
    return y, aux


# ---------------------------------------------------------------------------
# dense reference (oracle for tests)
# ---------------------------------------------------------------------------

def ref_moe(cfg: ModelConfig, p: Dict[str, Array], x: Array) -> Array:
    """Loop-over-experts oracle: exact top-k MoE with no capacity drops."""
    B, T, d = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    slots = live_slots(p["w1"])
    tpe = slots // E
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)
    if cfg.moe.router_norm_topk:
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    def expert_ff(e, xi):
        # reassemble expert e from its tpe slots
        w1 = jnp.concatenate([p["w1"][e * tpe + j] for j in range(tpe)], axis=-1)
        h = xi @ w1
        if cfg.mlp.startswith("gated"):
            w3 = jnp.concatenate([p["w3"][e * tpe + j] for j in range(tpe)], axis=-1)
            h = layers._act(cfg, h) * (xi @ w3)
        else:
            h = layers._act(cfg, h)
        w2 = jnp.concatenate([p["w2"][e * tpe + j] for j in range(tpe)], axis=-2)
        return h @ w2

    y = jnp.zeros_like(x)
    for e in range(E):
        fe = expert_ff(e, x)
        w = jnp.sum(jnp.where(eidx == e, gate, 0.0), axis=-1)
        y = y + fe * w[..., None].astype(x.dtype)
    if cfg.moe.shared_expert:
        y = y + layers.apply_mlp(cfg, p["shared"], x)
    return y
