"""Decode-time state: KV caches (full + sliding-window ring), Mamba conv/ssm
states, RWKV shift/wkv states.

Cache layout mirrors the parameter scan layout: ``cache["layers"]`` is a
tuple (one entry per scan-period position) of dicts whose leaves are stacked
over scan periods, so ``lax.scan`` can slice them alongside the params.

KV tensors are (B, H_kv, S, D): head_dim is the TP-sharded axis and the
seq-append ``dynamic_update_slice`` lands on an unsharded dim (DESIGN.md SS4)
— no masked full-cache rewrite under GSPMD. Sliding-window layers allocate
ring buffers of the window size only (O(w) memory at any context length).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.lora import scan_period


def position_cache_spec(cfg: ModelConfig, pos: int, batch: int, max_len: int,
                        kv_dtype=jnp.bfloat16):
    """(shape, dtype) tree for one scan position's cache (no stacking)."""
    kind = cfg.block_kind(pos)
    if kind == "attn":
        akind = cfg.attn_kind(pos)
        S = min(cfg.attn.window, max_len) if akind == "sliding" else max_len
        return {
            "k": ((batch, cfg.n_kv_heads, S, cfg.hd), kv_dtype),
            "v": ((batch, cfg.n_kv_heads, S, cfg.hd), kv_dtype),
            "len": ((batch,), jnp.int32),
        }
    if kind == "mamba":
        mc = cfg.mamba
        d_in = mc.expand * cfg.d_model
        return {
            "conv": ((batch, mc.d_conv - 1, d_in), kv_dtype),
            "ssm": ((batch, d_in, mc.d_state), jnp.float32),
        }
    if kind == "rwkv":
        rc = cfg.rwkv
        H = cfg.d_model // rc.head_dim
        return {
            "shift_t": ((batch, cfg.d_model), kv_dtype),
            "shift_c": ((batch, cfg.d_model), kv_dtype),
            "wkv": ((batch, H, rc.head_dim, rc.head_dim), jnp.float32),
        }
    raise KeyError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype=jnp.bfloat16):
    """Zero-initialized cache tree for decode (len == 0)."""
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    layers = []
    for pos in range(p):
        spec = position_cache_spec(cfg, pos, batch, max_len, kv_dtype)
        layers.append(jax.tree.map(
            lambda sd: jnp.zeros((n_sp,) + sd[0], sd[1]),
            spec, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple)))
    return {"layers": tuple(layers)}


def cache_spec_structs(cfg: ModelConfig, batch: int, max_len: int,
                       kv_dtype=jnp.bfloat16, sharding_fn=None):
    """ShapeDtypeStruct tree (for dry-run input specs), optionally sharded.

    ``sharding_fn(pos, leaf_name, shape)`` -> sharding or None."""
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    layers = []
    for pos in range(p):
        spec = position_cache_spec(cfg, pos, batch, max_len, kv_dtype)
        entry = {}
        for name, (shape, dt) in spec.items():
            full = (n_sp,) + shape
            sh = sharding_fn(pos, name, full) if sharding_fn else None
            entry[name] = jax.ShapeDtypeStruct(full, dt, sharding=sh)
        layers.append(entry)
    return {"layers": tuple(layers)}


def cache_len(cache) -> Optional[jax.Array]:
    """Per-batch-row lengths (B,) — or None for stateless-position archs."""
    for entry in cache["layers"]:
        if "len" in entry:
            return entry["len"][0]
    return None


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
