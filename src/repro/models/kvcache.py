"""Decode-time state: KV caches (full + sliding-window ring), Mamba conv/ssm
states, RWKV shift/wkv states.

Cache layout mirrors the parameter scan layout: ``cache["layers"]`` is a
tuple (one entry per scan-period position) of dicts whose leaves are stacked
over scan periods, so ``lax.scan`` can slice them alongside the params.

KV tensors are (B, H_kv, S, D): head_dim is the TP-sharded axis and the
seq-append ``dynamic_update_slice`` lands on an unsharded dim (DESIGN.md SS4)
— no masked full-cache rewrite under GSPMD. Sliding-window layers allocate
ring buffers of the window size only (O(w) memory at any context length).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import scan_period


def position_cache_spec(cfg: ModelConfig, pos: int, batch: int, max_len: int,
                        kv_dtype=jnp.bfloat16):
    """(shape, dtype) tree for one scan position's cache (no stacking)."""
    kind = cfg.block_kind(pos)
    if kind == "attn":
        akind = cfg.attn_kind(pos)
        S = min(cfg.attn.window, max_len) if akind == "sliding" else max_len
        return {
            "k": ((batch, cfg.n_kv_heads, S, cfg.hd), kv_dtype),
            "v": ((batch, cfg.n_kv_heads, S, cfg.hd), kv_dtype),
            "len": ((batch,), jnp.int32),
        }
    if kind == "mamba":
        mc = cfg.mamba
        d_in = mc.expand * cfg.d_model
        return {
            "conv": ((batch, mc.d_conv - 1, d_in), kv_dtype),
            "ssm": ((batch, d_in, mc.d_state), jnp.float32),
        }
    if kind == "rwkv":
        rc = cfg.rwkv
        H = cfg.d_model // rc.head_dim
        return {
            "shift_t": ((batch, cfg.d_model), kv_dtype),
            "shift_c": ((batch, cfg.d_model), kv_dtype),
            "wkv": ((batch, H, rc.head_dim, rc.head_dim), jnp.float32),
        }
    raise KeyError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype=jnp.bfloat16):
    """Zero-initialized cache tree for decode (len == 0)."""
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    layers = []
    for pos in range(p):
        spec = position_cache_spec(cfg, pos, batch, max_len, kv_dtype)
        layers.append(jax.tree.map(
            lambda sd: jnp.zeros((n_sp,) + sd[0], sd[1]),
            spec, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple)))
    return {"layers": tuple(layers)}


def cache_spec_structs(cfg: ModelConfig, batch: int, max_len: int,
                       kv_dtype=jnp.bfloat16, sharding_fn=None):
    """ShapeDtypeStruct tree (for dry-run input specs), optionally sharded.

    ``sharding_fn(pos, leaf_name, shape)`` -> sharding or None."""
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    layers = []
    for pos in range(p):
        spec = position_cache_spec(cfg, pos, batch, max_len, kv_dtype)
        entry = {}
        for name, (shape, dt) in spec.items():
            full = (n_sp,) + shape
            sh = sharding_fn(pos, name, full) if sharding_fn else None
            entry[name] = jax.ShapeDtypeStruct(full, dt, sharding=sh)
        layers.append(entry)
    return {"layers": tuple(layers)}


# ---------------------------------------------------------------------------
# Paged layout (serving): full-attention KV lives in fixed-size pages drawn
# from a shared pool; per-request block tables map positions -> pages. Total
# KV memory scales with the sum of *actual* context lengths, not
# max_slots x max_len, so admission is bounded by page occupancy. Sliding-
# window layers keep per-slot ring buffers (already O(window)); Mamba/RWKV
# states are per-slot and O(1) in sequence length — neither benefits from
# paging, so both keep the dense per-slot layout.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PagedLayout:
    """Geometry of the shared page pool.

    ``num_pages * page_size`` is the total token capacity across all
    concurrent requests; ``max_slots`` bounds the decode batch width."""

    page_size: int = 16
    num_pages: int = 256
    max_slots: int = 16

    @property
    def capacity_tokens(self) -> int:
        return self.page_size * self.num_pages

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


def position_paged_spec(cfg: ModelConfig, pos: int, layout: PagedLayout,
                        max_len: int, kv_dtype=jnp.float32):
    """(shape, dtype) tree for one scan position under the paged layout."""
    kind = cfg.block_kind(pos)
    B = layout.max_slots
    if kind == "attn":
        if cfg.attn_kind(pos) == "sliding":
            W = min(cfg.attn.window, max_len)
            return {
                "k": ((B, cfg.n_kv_heads, W, cfg.hd), kv_dtype),
                "v": ((B, cfg.n_kv_heads, W, cfg.hd), kv_dtype),
            }
        return {
            "kp": ((layout.num_pages, cfg.n_kv_heads, layout.page_size,
                    cfg.hd), kv_dtype),
            "vp": ((layout.num_pages, cfg.n_kv_heads, layout.page_size,
                    cfg.hd), kv_dtype),
        }
    # recurrent state: identical to the dense layout at batch = max_slots
    return position_cache_spec(cfg, pos, B, max_len, kv_dtype)


def init_paged_cache(cfg: ModelConfig, layout: PagedLayout, max_len: int,
                     kv_dtype=jnp.float32):
    """Zero-initialized paged cache tree (leaves stacked over scan periods)."""
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    layers = []
    for pos in range(p):
        spec = position_paged_spec(cfg, pos, layout, max_len, kv_dtype)
        layers.append(jax.tree.map(
            lambda sd: jnp.zeros((n_sp,) + sd[0], sd[1]),
            spec, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple)))
    return {"layers": tuple(layers)}


def reset_slots(cache, slots: Sequence[int]):
    """Zero the per-slot rows (ring KV + recurrent state) for reused slots.

    Page-pool leaves need no reset: a recycled page is only readable below
    the owning request's length, and every position below it is rewritten
    before it becomes visible."""
    if not slots:
        return cache
    idx = jnp.asarray(list(slots), jnp.int32)

    def zero_rows(name, leaf):
        if name in ("kp", "vp"):
            return leaf
        return leaf.at[:, idx].set(0)

    new_layers = tuple(
        {name: zero_rows(name, leaf) for name, leaf in entry.items()}
        for entry in cache["layers"])
    return {"layers": new_layers}


class SlotStateArena:
    """Checkpoint / restore / reset for per-slot decode state.

    Under the paged layout, full-attention KV is pool-addressed (``kp`` /
    ``vp`` plus a block table) and rolls back by rewinding the host-side
    write cursor. Everything else is *per-slot*: the sliding-window ring
    (``k``/``v`` keyed by slot row), the Mamba conv tail + SSM state
    (``conv``/``ssm``) and the RWKV token-shift + wkv state
    (``shift_t``/``shift_c``/``wkv``). Those leaves are cumulative over
    the whole stream, so a cursor rewind cannot rewind them — the serving
    engine instead snapshots them before each speculative verify chunk
    and blends the snapshot back (inside the same jitted step, via a
    per-slot select on the accepted-length scalar) when drafts are
    rejected.

    The tracked leaf names come from the kernel modules themselves
    (``attention.SLOT_STATE_LEAVES`` etc.), so a new token-mixer kind
    only has to declare its per-slot leaves to join the checkpoint path.
    ``tracked`` is False for full-attention-only models: every method is
    then a no-op and spec engines trace exactly the cursor-only path."""

    def __init__(self, cfg: ModelConfig):
        from repro.models import attention, rwkv, ssm
        per_pos: List[Tuple[str, ...]] = []
        for pos in range(scan_period(cfg)):
            kind = cfg.block_kind(pos)
            if kind == "attn":
                per_pos.append(tuple(attention.SLOT_STATE_LEAVES)
                               if cfg.attn_kind(pos) == "sliding" else ())
            elif kind == "mamba":
                per_pos.append(tuple(ssm.SLOT_STATE_LEAVES))
            elif kind == "rwkv":
                per_pos.append(tuple(rwkv.SLOT_STATE_LEAVES))
            else:
                raise KeyError(kind)
        self.leaves: Tuple[Tuple[str, ...], ...] = tuple(per_pos)
        self.tracked: bool = any(self.leaves)

    def snapshot(self, cache):
        """Copy the per-slot leaves (all slots at once). Called on the
        pre-chunk cache inside the jitted verify step; returns None when
        nothing is tracked so untracked engines add no HLO."""
        if not self.tracked:
            return None
        return tuple({n: entry[n] for n in names}
                     for entry, names in zip(cache["layers"], self.leaves))

    def restore(self, cache, ckpt, keep):
        """Per-slot select between post-chunk state and the checkpoint.

        ``keep`` is a (max_slots,) bool vector: True keeps the post-chunk
        state (full accept — the chunk's writes are all final), False
        restores the pre-chunk snapshot (any rejection — the accepted
        prefix is replayed by the engine as a resumed prefill chunk).
        Leaves are stacked (n_scan, max_slots, ...), so the select
        broadcasts over axis 1."""
        if not self.tracked:
            return cache
        new_layers = []
        for entry, names, ck in zip(cache["layers"], self.leaves, ckpt):
            entry = dict(entry)
            for n in names:
                after = entry[n]
                sel = keep.reshape((1, -1) + (1,) * (after.ndim - 2))
                entry[n] = jnp.where(sel, after, ck[n])
            new_layers.append(entry)
        return {"layers": tuple(new_layers)}

    def reset(self, cache, slots: Sequence[int]):
        """Zero the tracked per-slot rows for recycled slots, so a stale
        checkpoint or leftover ring/recurrent state can never leak into a
        fresh request that reuses the slot. Same coverage as
        :func:`reset_slots` restricted to the declared leaves — pool
        pages need no reset (only positions below the owner's length are
        ever readable, and those are rewritten first)."""
        if not (self.tracked and slots):
            return cache
        idx = jnp.asarray(list(slots), jnp.int32)
        new_layers = []
        for entry, names in zip(cache["layers"], self.leaves):
            entry = dict(entry)
            for n in names:
                entry[n] = entry[n].at[:, idx].set(0)
            new_layers.append(entry)
        return {"layers": tuple(new_layers)}


class PageAllocator:
    """Host-side refcounted free-list allocator over the shared pool.

    All-or-nothing allocation (a request either gets every page it needs or
    none), LIFO recycling so hot pages stay cache-resident. Pages carry
    refcounts so prefix-sharing requests (and the prefix index itself) can
    hold the same page: ``alloc`` hands out pages at refcount 1, ``incref``
    adds a holder, and ``decref``/``free`` release one — the page returns
    to the free list only when its count reaches zero (copy-on-write
    forking, not in-place mutation, is the only legal way to diverge)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refs: List[int] = [0] * num_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def shared_pages(self) -> int:
        """Pages with more than one holder (slot or prefix-index refs)."""
        return sum(1 for r in self._refs if r > 1)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0 or n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def incref(self, page: int) -> None:
        assert self._refs[page] > 0, f"incref of free page {page}"
        self._refs[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one holder; returns True iff the page actually freed."""
        assert 0 <= page < self.num_pages, page
        assert self._refs[page] > 0, f"double free of page {page}"
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            return True
        return False

    def free(self, pages: Sequence[int]) -> int:
        """Decref every page; returns how many were ACTUALLY reclaimed
        (shared pages survive their co-holders and don't add headroom)."""
        return sum(1 for p in pages if self.decref(p))

    def release_tail(self, pages: List[int], keep: int) -> int:
        """Speculative-decode rollback: drop this holder's ref on every
        page past the first ``keep`` and truncate ``pages`` in place.

        No device work is needed — a rewound write cursor makes stale KV
        entries past the new length invisible (the paged attend masks
        positions >= lens + chunk_lens), and any page co-held by another
        slot or the prefix index was CoW-forked before the speculative
        write, so the tail pages here are either refcount-1 (freed now)
        or still legitimately held elsewhere (survive the decref).
        Returns pages ACTUALLY reclaimed."""
        assert 0 <= keep <= len(pages), (keep, len(pages))
        freed = self.free(pages[keep:])
        del pages[keep:]
        return freed

    def check_invariants(self) -> None:
        assert len(set(self._free)) == len(self._free), "free-list dup"
        assert all(0 <= p < self.num_pages for p in self._free)
        for p in range(self.num_pages):
            in_free = p in self._free
            assert (self._refs[p] == 0) == in_free, \
                f"page {p}: refs={self._refs[p]} free={in_free}"


def fork_pages(cache, src: jax.Array, dst: jax.Array):
    """Copy-on-write fork: copy pool pages ``src[i] -> dst[i]`` in every
    paged (kp/vp) leaf. Reads all sources before any write (a single
    gather-then-scatter per leaf), so a page may legally appear both as a
    source and as another pair's destination within one call. Padding by
    repeating a real (src, dst) pair is allowed — duplicate pairs write
    identical values."""
    def cp(entry):
        out = {}
        for name, leaf in entry.items():
            if name in ("kp", "vp"):
                leaf = leaf.at[:, dst].set(leaf[:, src])
            out[name] = leaf
        return out
    return {"layers": tuple(cp(e) for e in cache["layers"])}


def gather_pages(cache, pages: Sequence[int]):
    """Snapshot pool page contents to host: one ``{"kp": arr, "vp": arr}``
    dict per scan position, each ``(n_sp, len(pages), Hkv, page, D)``.
    Used to serialize the prefix index (serve/prefix.py); works on sharded
    pools (the gather output is materialized host-side)."""
    idx = jnp.asarray(list(pages), jnp.int32)
    out = []
    for entry in cache["layers"]:
        out.append({name: np.asarray(leaf[:, idx])
                    for name, leaf in entry.items() if name in ("kp", "vp")})
    return out


def scatter_pages(cache, pages: Sequence[int], data):
    """Inverse of ``gather_pages``: write saved page contents into pool
    pages ``pages[i]`` of every kp/vp leaf. ``data`` is the per-position
    list ``gather_pages`` produced (possibly row-subset along its page
    dim). Preserves each leaf's dtype and sharding."""
    if not pages:
        return cache
    idx = jnp.asarray(list(pages), jnp.int32)
    new_layers = []
    for entry, saved in zip(cache["layers"], data):
        e = dict(entry)
        for name, arr in saved.items():
            leaf = entry[name]
            e[name] = leaf.at[:, idx].set(
                jnp.asarray(arr).astype(leaf.dtype))
        new_layers.append(e)
    return {"layers": tuple(new_layers)}


def cache_len(cache) -> Optional[jax.Array]:
    """Per-batch-row lengths (B,) — or None for stateless-position archs."""
    for entry in cache["layers"]:
        if "len" in entry:
            return entry["len"][0]
    return None


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
