"""Shared model layers: norms, RoPE, MLPs, embeddings.

All frozen-weight matmuls route through ``hetero.static_matmul`` (the
crossbar/ReRAM path); everything here is pure JAX and shape-polymorphic.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero
from repro.core.noise import NoiseConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape, dtype, fan_in: Optional[int] = None) -> Array:
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def init_norm(cfg: ModelConfig, dtype) -> Dict[str, Array]:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    hetero.record_nonlinear(x.size)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    hetero.record_nonlinear(x.size)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Dict[str, Array], x: Array) -> Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_sincos(positions: Array, head_dim: int, theta: float):
    """positions (B, T) -> sin/cos (B, T, head_dim/2) in f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, T, half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """x (B, T, H, D); rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[:, :, None, :], cos[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FF block)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Array]:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (d, ff), dtype)}
    if cfg.mlp.startswith("gated"):
        p["w3"] = dense_init(ks[2], (d, ff), dtype)
    p["w2"] = dense_init(ks[1], (ff, d), dtype, fan_in=ff)
    return p


def _act(cfg: ModelConfig, h: Array) -> Array:
    hetero.record_nonlinear(h.size)
    if "silu" in cfg.mlp:
        return jax.nn.silu(h)
    return jax.nn.gelu(h, approximate=True)


def apply_mlp(cfg: ModelConfig, p: Dict[str, Array], x: Array, *,
              noise: Optional[NoiseConfig] = None, rng: Optional[Array] = None,
              sharder=None) -> Array:
    """FF-1/FF-2 (Table II) — STATIC engine (ReRAM in the paper)."""
    h = hetero.static_matmul(x, p["w1"], noise=noise, rng=rng)
    if cfg.mlp.startswith("gated"):
        g = hetero.static_matmul(x, p["w3"], noise=noise, rng=rng)
        h = _act(cfg, h) * g
    else:
        h = _act(cfg, h)
    return hetero.static_matmul(h, p["w2"], noise=noise, rng=rng)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Array]:
    k1, k2 = jax.random.split(key)
    p = {"table": (0.02 * jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_tokens(cfg: ModelConfig, p: Dict[str, Array], tokens: Array,
                 dtype) -> Array:
    x = p["table"].astype(dtype)[tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return x


def unembed(cfg: ModelConfig, p: Dict[str, Array], x: Array) -> Array:
    if cfg.tie_embeddings:
        w = p["table"].astype(x.dtype).T
    else:
        w = p["unembed"]
    logits = hetero.static_matmul(x, w)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = (c * jnp.tanh(logits.astype(jnp.float32) / c)).astype(logits.dtype)
        hetero.record_nonlinear(logits.size)
    return logits
