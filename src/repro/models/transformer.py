"""Config-driven decoder: embeds -> scan over period-blocks -> norm -> head.

One ``apply_position`` handles any block kind (attn / mamba / rwkv) plus its
FF (dense or MoE); ``lax.scan`` runs over stacked scan-periods so the HLO
contains each distinct layer shape exactly once (essential for compiling
398B-param configs in the dry-run). LoRA adapters and decode caches mirror
the same layout and are scanned alongside.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero
from repro.core.lora import scan_period
from repro.core.noise import NoiseConfig
from repro.models import attention, layers, moe, rwkv, ssm

Array = jax.Array


@dataclass(frozen=True)
class ExecConfig:
    """Runtime execution knobs (orthogonal to the model config)."""

    attn_impl: str = "auto"         # auto | ref | blocked | banded | pallas
    block_q: int = 2048
    block_kv: int = 512
    remat: bool = False
    scan_layers: bool = True
    capacity_factor: Optional[float] = None
    moe_group_size: Optional[int] = None
    moe_dispatch: str = "capacity"  # capacity (training) | dropless (serving)
    noise: NoiseConfig = field(default_factory=NoiseConfig)
    act_dtype: Any = jnp.float32
    rwkv_impl: str = "auto"
    sharder: Optional[Callable[[Array, str], Array]] = None
    moe_parallel: int = 1           # expert slots >= this (mesh model width)

    def shard(self, x: Array, name: str) -> Array:
        return self.sharder(x, name) if self.sharder is not None else x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_position(cfg: ModelConfig, pos: int, key: Array, dtype,
                   moe_parallel: int) -> Dict:
    kind = cfg.block_kind(pos)
    ks = jax.random.split(key, 4)
    if kind == "rwkv":
        return rwkv.init_rwkv(cfg, ks[0], dtype)
    entry: Dict[str, Any] = {"norm": layers.init_norm(cfg, dtype),
                             "norm2": layers.init_norm(cfg, dtype)}
    if kind == "attn":
        entry["attn"] = attention.init_attn(cfg, ks[0], dtype)
    elif kind == "mamba":
        entry["mamba"] = ssm.init_mamba(cfg, ks[0], dtype)
    if cfg.is_moe_layer(pos):
        entry["ff"] = moe.init_moe(cfg, ks[1], dtype, moe_parallel)
    else:
        entry["ff"] = layers.init_mlp(cfg, ks[1], dtype)
    return entry


def init_params(cfg: ModelConfig, key: Array, dtype=jnp.float32,
                moe_parallel: int = 1) -> Dict:
    p = scan_period(cfg)
    n_sp = cfg.n_layers // p
    k_emb, k_layers = jax.random.split(key)
    pos_keys = jax.random.split(k_layers, p)
    layer_trees = []
    for pos in range(p):
        per_period = jax.random.split(pos_keys[pos], n_sp)
        stacked = jax.vmap(
            lambda k: _init_position(cfg, pos, k, dtype, moe_parallel)
        )(per_period)
        layer_trees.append(stacked)
    return {
        "embed": layers.init_embed(cfg, k_emb, dtype),
        "final_norm": layers.init_norm(cfg, dtype),
        "layers": tuple(layer_trees),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_position(cfg: ModelConfig, ec: ExecConfig, pos: int, x: Array,
                    pparams, plora, pcache, positions: Array, mode: str,
                    prefill_cache_len: Optional[int], rng, adapter_idx,
                    paged=None, chunk_lens=None
                    ) -> Tuple[Array, Any, Dict[str, Array]]:
    kind = cfg.block_kind(pos)
    aux: Dict[str, Array] = {}
    noise = ec.noise if (ec.noise.enabled and mode == "train") else None

    if kind == "rwkv":
        x, newc = rwkv.apply_rwkv_block(
            cfg, pparams, x, cache=pcache, lora=plora, adapter_idx=adapter_idx,
            noise=noise, rng=rng, impl=ec.rwkv_impl, sharder=ec.sharder,
            chunk_lens=chunk_lens)
        return ec.shard(x, "act"), newc, aux

    h = ec.shard(layers.apply_norm(cfg, pparams["norm"], x), "act")
    if kind == "attn":
        delta, newc = attention.apply_attention_block(
            cfg, pparams["attn"], h, positions,
            kind=cfg.attn_kind(pos), mode=mode, cache=pcache,
            prefill_cache_len=prefill_cache_len, lora=plora,
            adapter_idx=adapter_idx, noise=noise, rng=rng,
            impl=ec.attn_impl, block_q=ec.block_q, block_kv=ec.block_kv,
            sharder=ec.sharder, paged=paged,
            chunk_lens=chunk_lens if mode == "prefill" else None)
    elif kind == "mamba":
        h = ec.shard(h, "act_gathered")  # scan has cross-shard seq dependency
        delta, newc = ssm.apply_mamba_block(
            cfg, pparams["mamba"], h, cache=pcache, lora=plora,
            adapter_idx=adapter_idx, noise=noise, rng=rng, sharder=ec.sharder,
            chunk_lens=chunk_lens)
        delta = ec.shard(delta, "act")
    else:
        raise KeyError(kind)
    x = x + delta
    x = ec.shard(x, "act")

    h2 = ec.shard(layers.apply_norm(cfg, pparams["norm2"], x), "act")
    if cfg.is_moe_layer(pos):
        token_mask = None
        if chunk_lens is not None:
            token_mask = (jnp.arange(x.shape[1])[None, :]
                          < chunk_lens[:, None])
        ff_out, aux = moe.apply_moe(cfg, pparams["ff"], h2, noise=noise,
                                    rng=rng, capacity_factor=ec.capacity_factor,
                                    sharder=ec.sharder,
                                    group_size=ec.moe_group_size,
                                    token_mask=token_mask,
                                    dispatch=ec.moe_dispatch)
    else:
        ff_out = layers.apply_mlp(cfg, pparams["ff"], h2, noise=noise, rng=rng,
                                  sharder=ec.sharder)
    x = ec.shard(x + ff_out, "act")
    return x, newc, aux


def forward(cfg: ModelConfig, params: Dict, inputs: Dict[str, Array], *,
            lora: Optional[Dict] = None, cache: Optional[Dict] = None,
            positions: Optional[Array] = None, mode: str = "train",
            prefill_cache_len: Optional[int] = None,
            exec_cfg: ExecConfig = ExecConfig(), rng: Optional[Array] = None,
            adapter_idx: Optional[Array] = None,
            paged: Optional[Dict[str, Array]] = None,
            chunk_lens: Optional[Array] = None,
            ) -> Tuple[Array, Optional[Dict], Dict[str, Array]]:
    """Returns (logits (B,T,V), new_cache, aux).

    inputs: {"tokens": (B,T) int32} or {"embeds": (B,T,d)} (stub frontend).
    positions: (B,T) global token positions (defaults to arange / cache len).
    paged: block-table state for the paged decode path (see
    ``attention.apply_attention_block``); chunk_lens (B,) marks ragged
    chunks — rows are valid for their first chunk_lens[b] tokens only.
    aux carries "lb_loss" (summed MoE load-balance loss) and
    "moe_dropped_tokens" (capacity-dropped (token, expert) assignments
    summed over layers — identically 0 when exec_cfg.moe_dispatch is
    "dropless", the mode the serving engines force).
    """
    ec = exec_cfg
    P = scan_period(cfg)
    n_sp = cfg.n_layers // P

    if "tokens" in inputs:
        x = layers.embed_tokens(cfg, params["embed"], inputs["tokens"],
                                ec.act_dtype)
    else:
        x = inputs["embeds"].astype(ec.act_dtype)
    B, T = x.shape[0], x.shape[1]

    if positions is None:
        if mode == "decode" and cache is not None:
            from repro.models.kvcache import cache_len
            cur = cache_len(cache)
            if cur is None:
                positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
            else:
                positions = cur[:, None] + jnp.arange(T)[None]
        else:
            positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    positions = ec.shard(positions, "pos")
    x = ec.shard(x, "act")

    lora_layers = lora["layers"] if lora is not None else tuple({} for _ in range(P))
    cache_layers = cache["layers"] if cache is not None else tuple(None for _ in range(P))

    def period_fn(x, period_idx, pparams_t, plora_t, pcache_t, rng):
        new_caches = []
        all_aux = []
        for pos in range(P):
            prng = (jax.random.fold_in(rng, period_idx * P + pos)
                    if rng is not None else None)
            pc = pcache_t[pos] if pcache_t is not None else None
            if pc is None and mode == "prefill" and cfg.block_kind(pos) != "attn":
                # mamba/rwkv must emit their state from prefill: start at zero
                from repro.models.kvcache import position_cache_spec
                spec = position_cache_spec(cfg, pos, B, 1, ec.act_dtype)
                pc = {k: jnp.zeros(s, d) for k, (s, d) in spec.items()}
            x, newc, aux = _apply_position(
                cfg, ec, pos, x, pparams_t[pos], plora_t[pos], pc,
                positions, mode, prefill_cache_len, prng, adapter_idx,
                paged, chunk_lens)
            new_caches.append(newc)
            all_aux.append(aux)
        lb = sum([a.get("lb_loss", jnp.zeros((), jnp.float32)) for a in all_aux],
                 jnp.zeros((), jnp.float32))
        drop = sum([a.get("dropped_tokens", jnp.zeros((), jnp.float32))
                    for a in all_aux], jnp.zeros((), jnp.float32))
        return x, tuple(new_caches), lb, drop

    if ec.scan_layers and n_sp > 1:
        def scan_body(carry, xs):
            x, lb_acc, drop_acc = carry
            period_idx, pparams_t, plora_t, pcache_t = xs
            x, newc, lb, drop = period_fn(x, period_idx, pparams_t, plora_t,
                                          pcache_t, rng)
            return (x, lb_acc + lb, drop_acc + drop), newc

        if ec.remat:
            scan_body = jax.checkpoint(
                scan_body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (jnp.arange(n_sp), params["layers"], lora_layers,
              cache_layers if cache is not None else None)
        (x, lb_total, drop_total), new_cache_layers = jax.lax.scan(
            scan_body,
            (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), xs)
    else:
        lb_total = jnp.zeros((), jnp.float32)
        drop_total = jnp.zeros((), jnp.float32)
        new_cache_layers = []
        # unrolled: slice each period manually
        for sp in range(n_sp):
            pparams_t = jax.tree.map(lambda a: a[sp], params["layers"])
            plora_t = jax.tree.map(lambda a: a[sp], lora_layers)
            pcache_t = (jax.tree.map(lambda a: a[sp], cache_layers)
                        if cache is not None else None)
            x, newc, lb, drop = period_fn(x, sp, pparams_t, plora_t,
                                          pcache_t, rng)
            lb_total = lb_total + lb
            drop_total = drop_total + drop
            new_cache_layers.append(newc)
        if cache is not None or mode == "prefill":
            new_cache_layers = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_cache_layers)

    x = layers.apply_norm(cfg, params["final_norm"], x)
    x = ec.shard(x, "act_gathered")
    logits = layers.unembed(cfg, params["embed"], x)
    logits = ec.shard(logits, "logits")

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"layers": tuple(new_cache_layers)}
    aux = {"lb_loss": lb_total, "moe_dropped_tokens": drop_total}
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, logits: Array, labels: Array,
            mask: Optional[Array] = None) -> Tuple[Array, Dict[str, Array]]:
    """Token-mean cross entropy over (possibly vocab-sharded) logits.

    The label logit is extracted with a one-hot multiply-reduce rather than
    take_along_axis: gathers over a TP-sharded vocab axis make GSPMD
    replicate the whole logits tensor (53 GiB/device for llama4-scout at
    train_4k); multiply-reduce stays sharded and lowers to one tiny psum."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = (labels[..., None] == jnp.arange(lf.shape[-1])[None, None, :])
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    tot = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / tot
    return loss, {"nll_sum": jnp.sum(nll * mask), "tokens": tot}
