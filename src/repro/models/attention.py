"""Attention: the DYNAMIC-engine computation (Atleus MHA-2/MHA-3).

Three interchangeable implementations of the fused score+softmax+V step
(the paper adopts FlashAttention-2-style fusion, SS IV.A):

  * ``ref``     — materialized scores; oracle for tests & decode (T_q == 1).
  * ``blocked`` — lax.scan over KV blocks with running (max, sum, acc);
                  pure-JAX flash attention used for train/prefill lowering.
  * ``banded``  — sliding-window prefill: gathers only the KV band each
                  Q block can see (FLOPs scale with window, not seq —
                  8x reduction at 32k/w4096), then runs ``blocked`` inside.
  * pallas      — TPU kernel (repro.kernels.flash_attention), selected via
                  ``impl='pallas'``; validated in interpret mode.

Supports GQA (any q/kv head ratio), causal masking via explicit position
arrays (required under sequence-parallel Q sharding), sliding windows,
gemma2 logit softcapping, and invalid-slot masking for ring caches.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero
from repro.core.lora import lora_delta, lora_scale
from repro.core.noise import NoiseConfig
from repro.models import layers

Array = jax.Array

NEG_INF = -1e30

# Cache-leaf taxonomy under the paged serving layout. POOL_LEAVES are
# block-table addressed (full attention KV): a rejected speculative suffix
# rolls back by rewinding the host-side write cursor alone. The sliding
# ring keeps the last W tokens *keyed by slot row* — SLOT_STATE_LEAVES
# names those per-slot arrays so the serving ``SlotStateArena`` can
# snapshot / select-restore / zero them by slot id around verify chunks.
SLOT_STATE_LEAVES = ("k", "v")
POOL_LEAVES = ("kp", "vp")


def _mask(q_pos: Array, kv_pos: Array, window: Optional[int]) -> Array:
    """(B, Tq, S) bool. kv_pos == -1 marks invalid (unwritten ring slots)."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    m &= kv_pos[:, None, :] >= 0
    if window is not None:
        m &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    return m


def _softcap(scores: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return scores
    hetero.record_nonlinear(scores.size)
    return cap * jnp.tanh(scores / cap)


def ref_attention(q: Array, k: Array, v: Array, q_pos: Array, kv_pos: Array,
                  *, window: Optional[int] = None,
                  softcap: Optional[float] = None, sharder=None) -> Array:
    """q (B,T,Hq,D); k/v (B,S,Hkv,D) -> (B,T,Hq,D). f32 softmax.

    Decode with a head_dim-sharded KV cache: the scores constraint forces
    GSPMD to psum partial scores (tens of MB) instead of all-gathering the
    whole cache over tp (tens of GB/step)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, T, Hkv, G, D) * (D ** -0.5)
    s = hetero.dynamic_einsum("bthgd,bshd->bhgts", qg, k,
                              preferred_element_type=jnp.float32)
    if sharder is not None:
        s = sharder(s, "attn_scores")
    s = _softcap(s.astype(jnp.float32), softcap)
    m = _mask(q_pos, kv_pos, window)[:, None, None, :, :]
    s = jnp.where(m, s, NEG_INF)
    hetero.record_nonlinear(s.size)
    p = jax.nn.softmax(s, axis=-1)
    o = hetero.dynamic_einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v)
    return o.reshape(B, T, Hq, D)


def _blocked_kv(k, v, kv_pos, block_kv):
    B, S, Hkv, D = k.shape
    if S % block_kv != 0:
        pad = block_kv - S % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    nb = S // block_kv
    kb = k.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(B, nb, block_kv).transpose(1, 0, 2)
    return kb, vb, pb


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, softcap, block_kv,
                    sharder=None, folded=False):
    with jax.named_scope("flash_fused"):
        return _flash_fwd_scoped(q, k, v, q_pos, kv_pos, window, softcap,
                                 block_kv, sharder, folded)


def _flash_fwd_scoped(q, k, v, q_pos, kv_pos, window, softcap, block_kv,
                      sharder=None, folded=False):
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    sh = _flash_sharder(sharder, folded)
    qg = sh((q.reshape(B, T, Hkv, G, D) * (D ** -0.5)).astype(q.dtype), "flash_q")
    kb, vb, pb = _blocked_kv(k, v, kv_pos, block_kv)
    kb, vb, pb = sh(kb, "flash_kv"), sh(vb, "flash_kv"), sh(pb, "flash_pb")

    m0 = sh(jnp.full((B, Hkv, G, T), NEG_INF, jnp.float32), "flash_ml")
    l0 = sh(jnp.zeros((B, Hkv, G, T), jnp.float32), "flash_ml")
    a0 = sh(jnp.zeros((B, T, Hkv, G, D), jnp.float32), "flash_acc")

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk = blk
        s = hetero.dynamic_einsum("bthgd,bshd->bhgts", qg, kblk,
                                  preferred_element_type=jnp.float32)
        s = _softcap(s.astype(jnp.float32), softcap)
        msk = _mask(q_pos, pblk, window)[:, None, None, :, :]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None]
        acc = acc + hetero.dynamic_einsum(
            "bhgts,bshd->bthgd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B,Hkv,G,T)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    out = out.reshape(B, T, Hq, D).astype(q.dtype)
    return out, lse


def _flash_sharder(sharder, folded):
    if sharder is None:
        return lambda x, n: x
    suf = "_f" if folded else ""
    return lambda x, n: sharder(x, n + suf)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash(q, k, v, q_pos, kv_pos, window, softcap, block_kv, sharder=None,
           folded=False):
    return _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, softcap, block_kv,
                           sharder, folded)[0]


def _flash_fwd(q, k, v, q_pos, kv_pos, window, softcap, block_kv,
               sharder=None, folded=False):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, softcap,
                               block_kv, sharder, folded)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(window, softcap, block_kv, sharder, folded, res, dout):
    """FlashAttention-2 backward: recompute scores blockwise from (q,k,v,lse);
    nothing O(T*S) is ever materialized (the paper's fused score+softmax,
    SS IV.A ref [39], including the backward pass for LoRA fine-tuning)."""
    with jax.named_scope("flash_fused"):
        return _flash_bwd_scoped(window, softcap, block_kv, sharder, folded,
                                 res, dout)


def _flash_bwd_scoped(window, softcap, block_kv, sharder, folded, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    S = k.shape[1]
    c = D ** -0.5
    sh = _flash_sharder(sharder, folded)
    qg = sh((q.reshape(B, T, Hkv, G, D) * c).astype(q.dtype), "flash_q")
    kb, vb, pb = _blocked_kv(k, v, kv_pos, block_kv)
    kb, vb, pb = sh(kb, "flash_kv"), sh(vb, "flash_kv"), sh(pb, "flash_pb")
    do = sh(dout.reshape(B, T, Hkv, G, D), "flash_acc")
    # D_i = sum_d dout_i * out_i  (B,Hkv,G,T)
    drow = jnp.sum(do.astype(jnp.float32) * out.reshape(B, T, Hkv, G, D)
                   .astype(jnp.float32), axis=-1).transpose(0, 2, 3, 1)
    drow = sh(drow, "flash_ml")

    dq0 = sh(jnp.zeros((B, T, Hkv, G, D), jnp.float32), "flash_acc")

    def body(dq, blk):
        kblk, vblk, pblk = blk
        s = hetero.dynamic_einsum("bthgd,bshd->bhgts", qg, kblk,
                                  preferred_element_type=jnp.float32)
        s = s.astype(jnp.float32)
        if softcap is not None:
            t = jnp.tanh(s / softcap)
            sc = softcap * t
            dcap = 1.0 - jnp.square(t)
        else:
            sc = s
            dcap = None
        msk = _mask(q_pos, pblk, window)[:, None, None, :, :]
        p = jnp.where(msk, jnp.exp(sc - lse[..., None]), 0.0)
        dp = hetero.dynamic_einsum("bthgd,bshd->bhgts", do, vblk,
                                   preferred_element_type=jnp.float32)
        dv_b = hetero.dynamic_einsum("bhgts,bthgd->bshd",
                                     p.astype(do.dtype), do,
                                     preferred_element_type=jnp.float32)
        ds = p * (dp.astype(jnp.float32) - drow[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = ds.astype(q.dtype)
        dq = dq + hetero.dynamic_einsum("bhgts,bshd->bthgd", ds, kblk,
                                        preferred_element_type=jnp.float32)
        dk_b = hetero.dynamic_einsum("bhgts,bthgd->bshd", ds, qg,
                                     preferred_element_type=jnp.float32)
        return dq, (dk_b, dv_b)

    dq, (dk_s, dv_s) = jax.lax.scan(body, dq0, (kb, vb, pb))
    dq = (dq * c).reshape(B, T, Hq, D).astype(q.dtype)
    nb = dk_s.shape[0]
    dk = dk_s.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_kv, Hkv, D)
    dv = dv_s.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_kv, Hkv, D)
    dk = dk[:, :S].astype(k.dtype)
    dv = dv[:, :S].astype(v.dtype)
    import numpy as np
    zpos = np.zeros(q_pos.shape, jax.dtypes.float0)
    zkpos = np.zeros(kv_pos.shape, jax.dtypes.float0)
    return dq, dk, dv, zpos, zkpos


_flash.defvjp(_flash_fwd, _flash_bwd)


def blocked_attention(q: Array, k: Array, v: Array, q_pos: Array,
                      kv_pos: Array, *, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      block_kv: int = 512, sharder=None,
                      folded: bool = False) -> Array:
    """Flash-style streaming attention with a fused custom VJP:
    O(T*S) compute, O(T + block) memory in both passes."""
    return _flash(q, k, v, q_pos, kv_pos, window, softcap, block_kv, sharder,
                  folded)


def banded_attention(q: Array, k: Array, v: Array, q_pos: Array,
                     kv_pos: Array, *, window: int,
                     softcap: Optional[float] = None,
                     block_q: int = 2048, block_kv: int = 512,
                     sharder=None) -> Array:
    """Sliding-window attention where each Q block only touches its KV band.

    Requires T == S == len(kv) and aligned positions (prefill/train). The
    band for q block i is kv blocks [i - ceil(w/bq), i]; out-of-range blocks
    are clamped to 0 and masked via positions."""
    B, T, Hq, D = q.shape
    S = k.shape[1]
    assert T == S, "banded path is for self-attention prefill/train"
    bq = min(block_q, T)
    nqb = T // bq
    nband = -(-window // bq) + 1  # ceil(w/bq) + 1

    qb = q.reshape(B, nqb, bq, Hq, D)
    qpb = q_pos.reshape(B, nqb, bq)
    kb = k.reshape(B, nqb, bq, k.shape[2], D)
    vb = v.reshape(B, nqb, bq, v.shape[2], D)
    kpb = kv_pos.reshape(B, nqb, bq)

    idx = jnp.arange(nqb)[:, None] - jnp.arange(nband - 1, -1, -1)[None, :]
    oob = idx < 0
    idx = jnp.maximum(idx, 0)  # (nqb, nband)

    kband = jnp.take(kb, idx, axis=1)          # (B, nqb, nband, bq, Hkv, D)
    vband = jnp.take(vb, idx, axis=1)
    pband = jnp.take(kpb, idx, axis=1)         # (B, nqb, nband, bq)
    pband = jnp.where(oob[None, :, :, None], -1, pband)

    Bn = B * nqb
    kband = kband.reshape(Bn, nband * bq, k.shape[2], D)
    vband = vband.reshape(Bn, nband * bq, v.shape[2], D)
    pband = pband.reshape(Bn, nband * bq)
    qfold = qb.reshape(Bn, bq, Hq, D)
    qpfold = qpb.reshape(Bn, bq)

    out = blocked_attention(qfold, kband, vband, qpfold, pband,
                            window=window, softcap=softcap,
                            block_kv=min(block_kv, nband * bq),
                            sharder=sharder, folded=True)
    return out.reshape(B, T, Hq, D)


def attend(q, k, v, q_pos, kv_pos, *, kind: str, window: Optional[int],
           softcap: Optional[float], impl: str, block_q: int,
           block_kv: int, sharder=None) -> Array:
    window = window if kind == "sliding" else None
    T, S = q.shape[1], k.shape[1]
    if window is not None and window >= S:
        window = None   # sliding degenerates to full causal
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, q_pos, kv_pos, window=window,
                                      softcap=softcap)
    if impl == "ref" or T == 1 or S <= block_kv:
        return ref_attention(q, k, v, q_pos, kv_pos, window=window,
                             softcap=softcap, sharder=sharder)
    if (window is not None and T == S and T % min(block_q, T) == 0
            and window >= block_q and impl in ("auto", "banded", "blocked")):
        return banded_attention(q, k, v, q_pos, kv_pos, window=window,
                                softcap=softcap, block_q=block_q,
                                block_kv=block_kv, sharder=sharder)
    return blocked_attention(q, k, v, q_pos, kv_pos, window=window,
                             softcap=softcap, block_kv=block_kv,
                             sharder=sharder)


# ---------------------------------------------------------------------------
# Paged decode: scatter the chunk into pool pages / ring slots, gather the
# visible context back through the block table, and run a normal attend().
# Padded tail tokens of a ragged chunk scatter to an out-of-bounds index and
# are DROPPED (mode="drop"), so they can never corrupt ring slots or pages.
#
# Prefix-shared pages (serve/prefix.py) need no handling here: the gather is
# purely block-table-driven, so a page mapped by several tables is simply
# read by each, and visibility (`gpos < lens + clens`) masks any resident
# tokens beyond a sharer's own length (e.g. garbage past the matched point
# in a CoW-forked tail page). Writes never target a co-held page — the
# scheduler forks it into the writer's table first.
# ---------------------------------------------------------------------------


def _paged_pool_update(pool: Array, new: Array, page_ids: Array,
                       within: Array) -> Array:
    """pool (P, Hkv, page, D); new (B, T, Hkv, D); page_ids/within (B, T).
    Invalid targets carry page_id == P (out of bounds -> dropped)."""
    B, T = new.shape[:2]
    return pool.at[page_ids.reshape(-1), :, within.reshape(-1), :].set(
        new.reshape(B * T, *new.shape[2:]).astype(pool.dtype), mode="drop")


def _paged_attend(cfg: ModelConfig, q, k, v, positions, cache, paged, *,
                  kind, softcap, impl, block_q, block_kv, sharder):
    """Decode/chunked-prefill attention against a paged or ring cache.

    q/k/v: (B, T, H, D) for the current chunk. ``paged``: block_table
    (B, nb), lens (B,), chunk_lens (B,), page_size. Returns (out, new_entry).
    """
    B, T = q.shape[0], q.shape[1]
    lens, clens = paged["lens"], paged["chunk_lens"]
    valid = jnp.arange(T)[None, :] < clens[:, None]          # (B, T)

    if "kp" in cache:                                        # full attn: pool
        page = paged["page_size"]
        bt = paged["block_table"]                            # (B, nb)
        nb = bt.shape[1]
        n_pages = cache["kp"].shape[0]
        col = positions // page
        colc = jnp.clip(col, 0, nb - 1)
        pid = jnp.take_along_axis(bt, colc, axis=1)          # (B, T)
        ok = valid & (col < nb) & (pid >= 0)
        pid = jnp.where(ok, pid, n_pages)                    # OOB -> drop
        within = positions % page
        kp = _paged_pool_update(cache["kp"], k, pid, within)
        vp = _paged_pool_update(cache["vp"], v, pid, within)
        if sharder is not None:
            # tensor-parallel serving: the pool shards head_dim on the
            # model axis, so the scatter above lands shard-local (pages /
            # within-page dims replicate) and the block-table gather below
            # stays collective-free; q aligns with the hd-sharded pool and
            # the score contraction over D psums inside attend()
            kp = sharder(kp, "paged_pool")
            vp = sharder(vp, "paged_pool")
            q = sharder(q, "paged_q")
        safe_bt = jnp.maximum(bt, 0)
        kg = kp[safe_bt]                                     # (B, nb, Hkv, pg, D)
        vg = vp[safe_bt]
        S = nb * page
        kg = kg.transpose(0, 1, 3, 2, 4).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        vg = vg.transpose(0, 1, 3, 2, 4).reshape(B, S, cfg.n_kv_heads, cfg.hd)
        gpos = (jnp.arange(nb)[:, None] * page
                + jnp.arange(page)[None, :]).reshape(-1)     # (S,)
        visible = jnp.repeat(bt >= 0, page, axis=1)          # (B, S)
        end = (lens + clens)[:, None]
        kv_pos = jnp.where(visible & (gpos[None, :] < end), gpos[None, :], -1)
        new_entry = {"kp": kp, "vp": vp}
    else:                                                    # sliding: ring
        kc, vc = cache["k"], cache["v"]                      # (B, Hkv, W, D)
        W = kc.shape[2]
        # attend over [ring history ; in-chunk K/V]: the ring may not be
        # able to hold the whole chunk (T > W legal), so in-chunk tokens
        # attend each other directly and the ring supplies only history.
        i = jnp.arange(W)[None, :]
        last_hist = (lens - 1)[:, None]
        # ring slot i holds the latest position == i (mod W) <= lens-1;
        # never-written slots resolve to negative -> masked
        hist_pos = last_hist - ((last_hist - i) % W)
        kg = jnp.concatenate(
            [kc.transpose(0, 2, 1, 3).astype(q.dtype), k], axis=1)
        vg = jnp.concatenate(
            [vc.transpose(0, 2, 1, 3).astype(q.dtype), v], axis=1)
        kv_pos = jnp.concatenate(
            [hist_pos, jnp.where(valid, positions, -1)], axis=1)
        # write-back with last-wins masking: of chunk tokens sharing a ring
        # slot (t' = t + kW), only the latest valid one lands
        write = valid & (jnp.arange(T)[None, :] + W >= clens[:, None])
        slot = jnp.where(write, positions % W, W)            # OOB -> drop
        b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T)).reshape(-1)
        kc = kc.at[b_ix, :, slot.reshape(-1), :].set(
            k.reshape(B * T, cfg.n_kv_heads, cfg.hd).astype(kc.dtype),
            mode="drop")
        vc = vc.at[b_ix, :, slot.reshape(-1), :].set(
            v.reshape(B * T, cfg.n_kv_heads, cfg.hd).astype(vc.dtype),
            mode="drop")
        new_entry = {"k": kc, "v": vc}

    out = attend(q, kg.astype(q.dtype), vg.astype(q.dtype), positions, kv_pos,
                 kind=kind, window=cfg.attn.window,
                 softcap=softcap, impl=impl, block_q=block_q,
                 block_kv=block_kv, sharder=sharder)
    return out, new_entry


# ---------------------------------------------------------------------------
# Attention block (projections + cache plumbing)
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Array]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, cfg.q_dim), dtype),
        "wk": layers.dense_init(ks[1], (d, cfg.kv_dim), dtype),
        "wv": layers.dense_init(ks[2], (d, cfg.kv_dim), dtype),
        "wo": layers.dense_init(ks[3], (cfg.q_dim, d), dtype, fan_in=cfg.q_dim),
    }
    if cfg.attn.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), dtype)
        p["k_norm"] = jnp.ones((cfg.hd,), dtype)
    return p


def _qk_norm(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def apply_attention_block(
    cfg: ModelConfig, p: Dict[str, Array], x: Array, positions: Array, *,
    kind: str, mode: str = "train", cache: Optional[Dict[str, Array]] = None,
    prefill_cache_len: Optional[int] = None,
    lora: Optional[Dict] = None, adapter_idx: Optional[Array] = None,
    noise: Optional[NoiseConfig] = None, rng: Optional[Array] = None,
    impl: str = "auto", block_q: int = 2048, block_kv: int = 512,
    sharder=None, paged: Optional[Dict[str, Array]] = None,
    chunk_lens: Optional[Array] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """MHA-1..MHA-4 for one layer. Returns (out, new_cache).

    mode: "train" (no cache), "prefill" (self-attend + emit cache of
    ``prefill_cache_len``), "decode" (append to cache, attend over it).
    Cache layout: k/v (B, Hkv, S_cache, D) — head_dim is the TP-sharded dim
    so the seq append lands on an unsharded axis.

    ``paged`` switches decode to the paged/chunked path: the cache entry is
    a shared page pool (full attn) or a per-slot ring without a "len" leaf
    (sliding), request lengths live in ``paged["lens"]``, and the incoming
    (B, T) chunk may be ragged per row (``paged["chunk_lens"]``).

    ``chunk_lens`` (B,) makes PREFILL ragged: row ``b`` holds
    ``chunk_lens[b]`` real tokens followed by padding. Pad tokens are
    invisible as keys, the emitted cache ``len`` is the true per-row
    length, and the sliding ring is built from each row's last real
    tokens — so one bucketed prefill compile serves every prompt length
    (pad-row outputs are finite garbage the caller discards)."""
    B, T, d = x.shape
    scale = lora_scale(cfg)

    def proj(name, target):
        y = hetero.static_matmul(x, p[name], noise=noise, rng=rng)
        if lora is not None and target in lora:
            y = y + lora_delta(x, lora[target], scale, adapter_idx)
        return y

    q = proj("wq", "wq").reshape(B, T, cfg.n_heads, cfg.hd)
    k = proj("wk", "wk").reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = proj("wv", "wv").reshape(B, T, cfg.n_kv_heads, cfg.hd)

    if cfg.attn.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)

    sin, cos = layers.rope_sincos(positions, cfg.hd, cfg.attn.rope_theta)
    q = layers.apply_rope(q, sin, cos)
    k = layers.apply_rope(k, sin, cos)

    new_cache = None
    if mode == "decode" and paged is not None:
        out, new_cache = _paged_attend(
            cfg, q, k, v, positions, cache, paged, kind=kind,
            softcap=cfg.attn.logit_softcap, impl=impl, block_q=block_q,
            block_kv=block_kv, sharder=sharder)
    elif mode == "decode":
        assert cache is not None
        # ---- decode: append to (B, Hkv, S, D) cache ----
        # "len" is per-row (B,): slots in a continuous-batching arena sit at
        # different positions (scalar still accepted for uniform decode).
        cur = cache["len"]
        if cur.ndim == 0:
            cur = jnp.broadcast_to(cur, (B,))
        kc, vc = cache["k"], cache["v"]
        S_cache = kc.shape[2]
        k_t = k.transpose(0, 2, 1, 3)  # (B, Hkv, T, D)
        v_t = v.transpose(0, 2, 1, 3)

        def row_update(c, u, start):
            return jax.lax.dynamic_update_slice(c, u.astype(c.dtype),
                                                (0, start, 0))

        i = jnp.arange(S_cache)
        if kind == "sliding":
            W = S_cache
            kc = jax.vmap(row_update)(kc, k_t, cur % W)
            vc = jax.vmap(row_update)(vc, v_t, cur % W)
            # slot i holds the latest position == i (mod W) strictly < cur+T
            last = cur[:, None] + T - 1
            kv_pos = last - ((last - i[None, :]) % W)
        else:
            kc = jax.vmap(row_update)(kc, k_t, cur)
            vc = jax.vmap(row_update)(vc, v_t, cur)
            kv_pos = jnp.where(i[None, :] < cur[:, None] + T, i[None, :], -1)
        new_cache = {"k": kc, "v": vc, "len": cur + T}
        if sharder is not None:
            kc = sharder(kc, "kv_cache")
            vc = sharder(vc, "kv_cache")
            q = sharder(q, "decode_q")   # align q with the hd-sharded cache
        k_at, v_at = kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3)
        out = attend(q, k_at.astype(q.dtype), v_at.astype(q.dtype), positions,
                     kv_pos, kind=kind, window=cfg.attn.window,
                     softcap=cfg.attn.logit_softcap, impl=impl,
                     block_q=block_q, block_kv=block_kv, sharder=sharder)
    else:
        # ---- train / prefill: self-attention ----
        if sharder is not None:   # gather KV over the model axis (SP)
            k = sharder(k, "kv_gathered")
            v = sharder(v, "kv_gathered")
        kv_pos = positions
        if mode == "prefill" and chunk_lens is not None:
            # ragged bucketed prefill: the padded tail is invisible as keys
            kv_pos = jnp.where(jnp.arange(T)[None, :] < chunk_lens[:, None],
                               kv_pos, -1)
        if sharder is not None:
            kv_pos = sharder(kv_pos, "pos_gathered")
        out = attend(q, k, v, positions, kv_pos, kind=kind,
                     window=cfg.attn.window, softcap=cfg.attn.logit_softcap,
                     impl=impl, block_q=block_q, block_kv=block_kv,
                     sharder=sharder)
        if mode == "prefill":
            S_cache = prefill_cache_len if prefill_cache_len is not None else T
            k_t = k.transpose(0, 2, 1, 3)  # (B, Hkv, T_full, D)
            v_t = v.transpose(0, 2, 1, 3)
            T_full = k_t.shape[2]
            if kind == "sliding":
                W = min(cfg.attn.window, S_cache)
                i = jnp.arange(W)
                # slot i holds the latest position == i (mod W) below the
                # row's real length (T_full when the chunk is not ragged)
                last = (jnp.full((B, 1), T_full, jnp.int32)
                        if chunk_lens is None else chunk_lens[:, None]) - 1
                slot_src = last - ((last - i[None, :]) % W)   # (B, W)
                src = jnp.clip(slot_src, 0, max(T_full - 1, 0))
                kc = jnp.take_along_axis(k_t, src[:, None, :, None], axis=2)
                vc = jnp.take_along_axis(v_t, src[:, None, :, None], axis=2)
            else:
                pad = S_cache - T_full
                kc = jnp.pad(k_t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vc = jnp.pad(v_t, ((0, 0), (0, 0), (0, pad), (0, 0)))
            lens_out = (jnp.full((B,), T_full, jnp.int32)
                        if chunk_lens is None
                        else chunk_lens.astype(jnp.int32))
            new_cache = {"k": kc.astype(q.dtype), "v": vc.astype(q.dtype),
                         "len": lens_out}
            if sharder is not None:
                new_cache["k"] = sharder(new_cache["k"], "kv_cache")
                new_cache["v"] = sharder(new_cache["v"], "kv_cache")

    out = out.reshape(B, T, cfg.q_dim)
    y = hetero.static_matmul(out, p["wo"], noise=noise, rng=rng)
    if lora is not None and "wo" in lora:
        y = y + lora_delta(out, lora["wo"], scale, adapter_idx)
    return y, new_cache
