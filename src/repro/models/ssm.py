"""Mamba selective-state-space block (jamba's 7-of-8 layers).

The projections (in/out/x/dt) are STATIC-engine matmuls (crossbar-
quantizable frozen weights); the selective scan itself is a dynamic
recurrence with no weight-stationary form — it runs on the DYNAMIC engine
(DESIGN.md SS5). The scan is chunked: sequential ``lax.scan`` over chunks of
``cfg.mamba.chunk`` steps carrying the (B, d_in, N) state, with a parallel
``associative_scan`` inside each chunk — O(T) work, O(B*chunk*d_in*N)
transient memory (d_in is TP-sharded so this divides by the mesh width).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hetero
from repro.core.noise import NoiseConfig
from repro.models import layers

Array = jax.Array

# Per-slot decode-state leaves: the conv tail holds the last K-1 inputs and
# the SSM state is cumulative over the whole stream, both indexed by slot
# row (batch dim). The serving ``SlotStateArena`` snapshots / restores /
# zeroes them by slot id — a paged-KV cursor rewind cannot rewind them.
SLOT_STATE_LEAVES = ("conv", "ssm")


def init_mamba(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Array]:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    r = mc.rank(d)
    N = mc.d_state
    ks = jax.random.split(key, 6)
    dt = jnp.exp(jax.random.uniform(ks[4], (d_in,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": (0.1 * jax.random.normal(ks[5], (mc.d_conv, d_in))).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": layers.dense_init(ks[1], (d_in, r + 2 * N), dtype, fan_in=d_in),
        "dt_proj": layers.dense_init(ks[2], (r, d_in), dtype, fan_in=r),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32),
                                          (d_in, N))).copy(),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers.dense_init(ks[3], (d_in, d), dtype, fan_in=d_in),
    }


def _causal_conv(x: Array, w: Array, b: Array, state: Optional[Array],
                 valid_len: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv over time. x (B,T,C), w (K,C).
    ``state`` (B, K-1, C) carries the tail of the previous segment.
    ``valid_len`` (B,) marks ragged chunks: the emitted state is the tail of
    the last K-1 *valid* inputs per row, so padded tails never leak."""
    B, T, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, T+K-1, C)
    out = jnp.zeros_like(x, shape=(B, T, C))
    wf = w.astype(jnp.float32)
    acc = jnp.zeros((B, T, C), jnp.float32)
    for j in range(K):
        acc = acc + xp[:, j:j + T, :].astype(jnp.float32) * wf[j]
    out = acc + b.astype(jnp.float32)
    if K == 1:
        new_state = state
    elif valid_len is None:
        new_state = xp[:, T:, :]
    else:
        # valid inputs occupy xp rows [0, K-1+len); their K-1 tail starts
        # at row len (clipped so len==0 keeps the incoming state)
        start = jnp.clip(valid_len, 0, T)
        new_state = jax.vmap(
            lambda row, s: jax.lax.dynamic_slice(row, (s, 0), (K - 1, C))
        )(xp, start)
    hetero.record_nonlinear(x.size * K)
    return out.astype(x.dtype), new_state.astype(x.dtype)


def _selective_scan(dt: Array, Bc: Array, Cc: Array, xi: Array, A: Array,
                    h0: Array, chunk: int, sharder=None) -> Tuple[Array, Array]:
    """Chunked selective scan: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;
    y_t = C_t . h_t.  dt/xi (B,T,D) f32; Bc/Cc (B,T,N); A (D,N); h0 (B,D,N).

    The (B, chunk, D, N) decay/increment tensors are built *inside* the
    checkpointed chunk body (never materialized for the whole sequence) and
    the C-contraction happens in-chunk, so transient memory is
    O(B*chunk*D*N) and the backward saves only chunk-boundary states."""
    B, T, D = dt.shape
    N = A.shape[-1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> identity step
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
    nc = dt.shape[1] // L
    sh = sharder if sharder is not None else (lambda x, n: x)
    h0 = sh(h0, "ssm_state")

    def to_chunks(x):
        return sh(x.reshape(B, nc, L, x.shape[-1]).transpose(1, 0, 2, 3),
                  "ssm_chunks")

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    @jax.checkpoint
    def chunk_body(h, xs):
        dt_c, b_c, c_c, x_c = xs                      # (B, L, .)
        a = jnp.exp(dt_c[..., None] * A)              # (B, L, D, N)
        bx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        cum_a, cum_b = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_all = cum_a * sh(h, "ssm_state")[:, None] + cum_b   # (B, L, D, N)
        y = hetero.dynamic_einsum("bldn,bln->bld", h_all, c_c)
        return sh(h_all[:, -1], "ssm_state"), y

    h_fin, ys = jax.lax.scan(chunk_body, h0,
                             (to_chunks(dt), to_chunks(Bc), to_chunks(Cc),
                              to_chunks(xi)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, nc * L, D)
    return y[:, :T], h_fin


def apply_mamba_block(
    cfg: ModelConfig, p: Dict[str, Array], x: Array, *,
    cache: Optional[Dict[str, Array]] = None,
    lora: Optional[Dict] = None, adapter_idx=None,
    noise: Optional[NoiseConfig] = None, rng: Optional[Array] = None,
    sharder=None, chunk_lens: Optional[Array] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """x (B,T,d) -> (y, new_cache). cache: {conv (B,K-1,d_in), ssm (B,d_in,N)}.

    ``chunk_lens`` (B,) marks ragged decode chunks: rows are only valid for
    their first ``chunk_lens[b]`` tokens. Padded steps run with dt == 0 (an
    identity state transition), so the SSM state a row emits is exactly the
    state after its last valid token."""
    from repro.core.lora import lora_delta, lora_scale

    mc = cfg.mamba
    B, T, d = x.shape
    d_in = mc.expand * d
    N = mc.d_state
    r = mc.rank(d)

    xz = hetero.static_matmul(x, p["in_proj"], noise=noise, rng=rng)
    if lora is not None and "mamba_in" in lora:
        xz = xz + lora_delta(x, lora["mamba_in"], lora_scale(cfg), adapter_idx)
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state,
                                valid_len=chunk_lens)
    xi = jax.nn.silu(xi)
    hetero.record_nonlinear(xi.size)

    dbc = hetero.static_matmul(xi, p["x_proj"], noise=noise, rng=rng)
    dt_r, Bc, Cc = jnp.split(dbc, [r, r + N], axis=-1)
    dt = hetero.static_matmul(dt_r, p["dt_proj"], noise=noise, rng=rng)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,T,d_in)
    if chunk_lens is not None:
        # padded tail steps become identity transitions (dt=0 -> a=1, bx=0)
        valid = jnp.arange(T)[None, :] < chunk_lens[:, None]
        dt = dt * valid[:, :, None]
    A = -jnp.exp(p["A_log"])                                         # (d_in, N)
    hetero.record_nonlinear(dt.size * 2 * N)

    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, d_in, N), jnp.float32))
    y, h_fin = _selective_scan(dt, Bc.astype(jnp.float32),
                               Cc.astype(jnp.float32),
                               xi.astype(jnp.float32), A, h0, mc.chunk,
                               sharder=sharder)
    y = y + p["D"] * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    hetero.record_nonlinear(y.size)

    out = hetero.static_matmul(y, p["out_proj"], noise=noise, rng=rng)
    if lora is not None and "mamba_out" in lora:
        out = out + lora_delta(y, lora["mamba_out"], lora_scale(cfg), adapter_idx)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": h_fin.astype(cache["ssm"].dtype)}
    return out, new_cache
