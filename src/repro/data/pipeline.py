"""Deterministic, shardable, resumable token pipeline.

Design requirements for 1000+-node training (DESIGN.md SS4):

  * **stateless indexing** — batch contents are a pure function of
    (seed, step, sample index). Restarting from a checkpoint at step k
    reproduces exactly the batches k, k+1, ... with no sampler state to
    save, and elastic resharding just changes which indices a host draws.
  * **shardable** — a host materializes only its slice of the global batch.
  * **learnable synthetic corpus** — no internet in this container, so the
    "WikiText-like" corpus is a seeded Zipfian bigram language: strong
    first-order structure a model can learn (perplexity drops from ~ln V
    to the process entropy), which is what the Fig. 13 quantization-
    perplexity benchmark needs.

A memmap-backed dataset with the same interface covers real tokenized
corpora.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    index: int = 0
    count: int = 1


class SyntheticLM:
    """Seeded Zipfian-bigram language model corpus."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        self.vocab_size = vocab_size
        self.seed = seed
        self.branch = branch
        rng = np.random.default_rng(seed)
        # each token has `branch` likely successors with Zipf weights
        self._succ = rng.integers(0, vocab_size,
                                  size=(vocab_size, branch)).astype(np.int64)
        w = 1.0 / np.arange(1, branch + 1)
        self._w = (w / w.sum()).astype(np.float64)

    def entropy_bound(self) -> float:
        return float(-(self._w * np.log(self._w)).sum())

    def sequence(self, idx: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed * 0x9E3779B9 + idx) & 0xFFFFFFFF)
        out = np.empty(seq_len + 1, np.int32)
        tok = int(rng.integers(0, self.vocab_size))
        for t in range(seq_len + 1):
            out[t] = tok
            nxt = rng.choice(self.branch, p=self._w)
            tok = int(self._succ[tok, nxt])
        return out

    def batch(self, step: int, global_batch: int, seq_len: int,
              shard: ShardInfo = ShardInfo()) -> Dict[str, np.ndarray]:
        """Local slice of the global batch for this shard."""
        assert global_batch % shard.count == 0
        local = global_batch // shard.count
        lo = shard.index * local
        seqs = np.stack([
            self.sequence(step * global_batch + lo + i, seq_len)
            for i in range(local)
        ])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


class MemmapLM:
    """Flat token file: deterministic strided windows (same interface)."""

    def __init__(self, path: str, vocab_size: int, seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.vocab_size = vocab_size
        self.seed = seed

    def batch(self, step: int, global_batch: int, seq_len: int,
              shard: ShardInfo = ShardInfo()) -> Dict[str, np.ndarray]:
        assert global_batch % shard.count == 0
        local = global_batch // shard.count
        lo = shard.index * local
        n_win = (len(self.tokens) - 1) // seq_len
        rng = np.random.default_rng(self.seed)
        perm_base = rng.permutation(n_win)
        idx = [(step * global_batch + lo + i) % n_win for i in range(local)]
        rows = []
        for i in idx:
            s = perm_base[i] * seq_len
            rows.append(np.asarray(self.tokens[s:s + seq_len + 1]))
        seqs = np.stack(rows)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def make_dataset(vocab_size: int, seed: int = 0,
                 path: Optional[str] = None):
    if path:
        return MemmapLM(path, vocab_size, seed)
    return SyntheticLM(vocab_size, seed)
