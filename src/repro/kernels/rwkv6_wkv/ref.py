"""Oracle: the model's chunk-checkpointed lax.scan implementation."""
from repro.models.rwkv import wkv_scan as rwkv6_wkv_ref  # noqa: F401
