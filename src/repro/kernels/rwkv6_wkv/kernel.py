"""Pallas TPU kernel: RWKV6 wkv recurrence with VMEM-resident state.

The (N, N) per-head state never leaves VMEM while T steps stream past —
the output-stationary dataflow the paper assigns to dynamic recurrences
(DESIGN.md SS5): under XLA the sequential scan writes the state to HBM
every step (the dominant term of rwkv6-7b's memory roofline); here it is
scratch that persists across the time-block grid dimension.

Grid: (B*H, T/bt). Inside a block, a fori_loop walks bt steps entirely in
registers/VMEM:   y_t = r_t (S + u ⊙ k_t v_t^T);  S <- diag(w_t) S + k_t v_t^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_ref, *, n_t, block_t):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    def step(i, _):
        rt = r_ref[0, i]                        # (N,)
        kt = k_ref[0, i]
        vt = v_ref[0, i]
        wt = w_ref[0, i]
        s = s_ref[...]                          # (N, N)
        kv = kt[:, None] * vt[None, :]
        y = jnp.sum(rt[:, None] * (s + u_ref[0][:, None] * kv), axis=0)
        y_ref[0, i] = y.astype(y_ref.dtype)
        s_ref[...] = wt[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(t_blk == n_t - 1)
    def _done():
        sout_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_wkv_kernel(r, k, v, w, u, s0, *, block_t=64, interpret=True):
    """r/k/v/w (BH, T, N) f32; u (BH, N); s0 (BH, N, N).
    Returns y (BH, T, N), s_final (BH, N, N)."""
    BH, T, N = r.shape
    assert T % block_t == 0
    n_t = T // block_t
    grid = (BH, n_t)
    kern = functools.partial(_wkv_kernel, n_t=n_t, block_t=block_t)
    seq_spec = pl.BlockSpec((1, block_t, N), lambda b, t: (b, t, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, N), lambda b, t: (b, 0)),
            pl.BlockSpec((1, N, N), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, N, N), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
