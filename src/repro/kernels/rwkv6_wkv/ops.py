"""Public wrapper: (B, T, H, N) layout, head folding, T padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import rwkv6_wkv_kernel


def rwkv6_wkv(r, k, v, w, u, s0, *, block_t=64, interpret=True):
    """r/k/v/w (B, T, H, N) f32; u (H, N); s0 (B, H, N, N)."""
    B, T, H, N = r.shape
    bt = min(block_t, T)
    pad = (-T) % bt

    def fold(x, fill=0.0):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)), constant_values=fill)
        return x.astype(jnp.float32)

    rf, kf, vf = fold(r), fold(k), fold(v)
    wf = fold(w, fill=1.0)   # padded steps: identity state update
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N).astype(jnp.float32)
    s0f = s0.reshape(B * H, N, N).astype(jnp.float32)
    y, s_fin = rwkv6_wkv_kernel(rf, kf, vf, wf, uf, s0f, block_t=bt,
                                interpret=interpret)
    y = y[:, :T].reshape(B, H, T, N).transpose(0, 2, 1, 3)
    return y, s_fin.reshape(B, H, N, N)
