"""Oracle: repro.models.attention.ref_attention (materialized f32 softmax)."""
from repro.models.attention import ref_attention as flash_attention_ref  # noqa: F401
