"""Public wrapper: (B, T, H, D) layout in/out, GQA folding, padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def flash_attention(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
                    block_q=128, block_kv=128, interpret=True):
    """q (B, T, Hq, D); k/v (B, S, Hkv, D); positions (B, T)/(B, S)."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    bq = min(block_q, T)
    bk = min(block_kv, S)
    pad_t = (-T) % bq
    pad_s = (-S) % bk
    group = Hq // Hkv

    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    qp = jnp.repeat(q_pos, Hq, axis=0).reshape(B * Hq, T)
    kp = jnp.repeat(kv_pos, Hkv, axis=0).reshape(B * Hkv, S)
    if pad_t:
        qf = jnp.pad(qf, ((0, 0), (0, pad_t), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, pad_t)))
    if pad_s:
        kf = jnp.pad(kf, ((0, 0), (0, pad_s), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_s), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad_s)), constant_values=-1)

    out = flash_attention_kernel(qf, kf, vf, qp, kp, window=window,
                                 softcap=softcap, block_q=bq, block_kv=bk,
                                 interpret=interpret)
    if pad_t:
        out = out[:, :T]
    return out.reshape(B, Hq, T, D).transpose(0, 2, 1, 3)
