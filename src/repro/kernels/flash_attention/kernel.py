"""Pallas TPU kernel: fused score+softmax+V attention (Atleus's DYNAMIC
engine / systolic-array computation, SS IV.A ref [39]).

Output-stationary dataflow: the (bq, D) output accumulator and the running
(max, sum) statistics live in VMEM scratch across the KV grid dimension
while K/V blocks stream from HBM — the direct analogue of the paper's OS
systolic mapping for dynamic-operand matmuls. Supports GQA via the kv-head
index map, causal/sliding masks from explicit position vectors, and gemma2
logit softcapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, out_ref,
                 acc_ref, m_ref, l_ref, *, n_kv, scale, window, softcap):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale      # (bq, D)
    k = k_ref[0].astype(jnp.float32)              # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = qpos_ref[0]                              # (bq,)
    kp = kpos_ref[0]                              # (bk,)
    mask = (kp[None, :] <= qp[:, None]) & (kp[None, :] >= 0)
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(kb == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_kernel(q, k, v, q_pos, kv_pos, *, window=None,
                           softcap=None, block_q=128, block_kv=128,
                           interpret=True):
    """q (BH, T, D); k/v (BHkv, S, D); q_pos (BH, T); kv_pos (BHkv, S).
    BH == B*Hq, BHkv == B*Hkv with Hq grouped per kv head (GQA): program
    (bh, ...) reads kv block bh // group."""
    BH, T, D = q.shape
    BHkv, S, _ = k.shape
    group = BH // BHkv
    assert T % block_q == 0 and S % block_kv == 0
    n_kv = S // block_kv
    grid = (BH, T // block_q, 1, n_kv)
    scale = D ** -0.5

    kern = functools.partial(_attn_kernel, n_kv=n_kv, scale=scale,
                             window=window, softcap=softcap)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, i, j, kb: (b, i)),
            pl.BlockSpec((1, block_kv), lambda b, i, j, kb: (b // group, kb)),
            pl.BlockSpec((1, block_q, D), lambda b, i, j, kb: (b, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j, kb: (b // group, kb, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j, kb: (b // group, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j, kb: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, kv_pos, q, k, v)
