"""Pallas TPU kernel: crossbar-wise quantized matmul with post-accumulation
dequantization (Atleus SS IV.D, Fig. 5).

The ReRAM crossbar geometry (128x128 cells, one quantization scale per
crossbar, dequant applied to the *accumulated* MVM output by the extra
shift-and-add stage) maps 1:1 onto MXU tiling:

  * weights live in HBM as int8 codes (int4: two-per-byte packed along K)
    plus one f32 scale per (128,128) block — exactly the crossbar layout;
  * the grid walks (M/bm, N/bn, K/128); each step runs the (bm,128)x(128,bn)
    MXU pass on the *codes* and applies the block scale to the f32
    accumulator tile — dequantization after accumulation, once per
    crossbar, not per weight element (the GPU ordering the paper beats);
  * the f32 accumulator tile is VMEM-resident scratch across the K grid
    dimension (TPU grids execute the minor dimension sequentially).

Weight-stationary semantics: codes/scales are loop-invariant operands (the
"conductances"); only activations stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 256
CROSSBAR = 128  # ReRAM crossbar size == MXU tile == quantization block


def _kernel_int8(x_ref, codes_ref, scale_ref, out_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, 128)
    w = codes_ref[...].astype(jnp.float32)        # (128, bn) int8 codes
    partial = jnp.dot(x, w, preferred_element_type=jnp.float32)
    # post-MVM dequantization: one scale per 128x128 crossbar
    acc_ref[...] += partial * scale_ref[0, 0]

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _kernel_int4(x_ref, codes_ref, scale_ref, out_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, 128)
    packed = codes_ref[...]                       # (64, bn) uint8, 2 nibbles
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    # unpack interleaved along K: rows (2i, 2i+1) <- (lo_i, hi_i)
    w = jnp.stack([lo, hi], axis=1).reshape(CROSSBAR, -1).astype(jnp.float32)
    partial = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_ref[...] += partial * scale_ref[0, 0]

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n",
                                             "interpret", "out_dtype"))
def crossbar_matmul(x, codes, scales, *, bits: int = 8,
                    block_m: int = DEFAULT_BLOCK_M, block_n: int = CROSSBAR,
                    interpret: bool = True, out_dtype=None):
    """x (M, K) @ dequant(codes, scales) -> (M, N).

    codes: int8 (K, N) for 8-bit, uint8 (K//2, N) packed for 4-bit.
    scales: f32 (K/128, N/128). M, K, N must be multiples of the tile sizes
    (the ops wrapper pads)."""
    M, K = x.shape
    N = codes.shape[1]
    out_dtype = out_dtype or x.dtype
    assert M % block_m == 0 and N % block_n == 0 and K % CROSSBAR == 0
    assert block_n == CROSSBAR, "one scale per crossbar: bn == 128"
    n_k = K // CROSSBAR
    grid = (M // block_m, N // block_n, n_k)

    if bits == 8:
        kern = functools.partial(_kernel_int8, n_k=n_k)
        codes_spec = pl.BlockSpec((CROSSBAR, block_n), lambda i, j, k: (k, j))
    elif bits == 4:
        kern = functools.partial(_kernel_int4, n_k=n_k)
        codes_spec = pl.BlockSpec((CROSSBAR // 2, block_n), lambda i, j, k: (k, j))
    else:
        raise ValueError(bits)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, CROSSBAR), lambda i, j, k: (i, k)),
            codes_spec,
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales)
