"""jit'd public wrapper: QuantizedTensor in, padding/tiling handled here.

On TPU (``interpret=False``) this is the STATIC-engine execution path for
every frozen-weight matmul; on CPU it runs the same kernel body in
interpret mode (tests) while the model's XLA fallback path is used for
large lowering."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.kernels.crossbar_matmul.kernel import (CROSSBAR, DEFAULT_BLOCK_M,
                                                  crossbar_matmul as _kernel)


def crossbar_matmul(x, qt: QuantizedTensor, *, block_m: int = DEFAULT_BLOCK_M,
                    interpret: bool = True, out_dtype=None):
    """x (..., K) @ qt (K, N) -> (..., N) via the Pallas crossbar kernel."""
    assert qt.ndim == 2, "2D weights (batched experts loop in the caller)"
    K, N = qt.orig_shape
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    pad_m = (-M) % block_m
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    pk = qt.codes.shape[0] * (2 if qt.bits == 4 else 1)
    if pk != K:                      # quantizer padded K to a 128 multiple
        x2 = jnp.pad(x2, ((0, 0), (0, pk - K)))
    y = _kernel(x2, qt.codes, qt.scales, bits=qt.bits, block_m=block_m,
                interpret=interpret, out_dtype=out_dtype or x.dtype)
    pn = qt.codes.shape[1]
    if pn != N:
        y = y[:, :N]
    if pad_m:
        y = y[:M]
    return y.reshape(*lead, N)
