"""Pure-jnp oracle for the crossbar matmul: dequantize-then-matmul in f32.
Mathematically identical to post-accumulation per-block dequant (scales
factor out of each 128-row block's partial sum)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, dequantize


def crossbar_matmul_ref(x, qt: QuantizedTensor, out_dtype=None):
    w = dequantize(qt, jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), w)
    return y.astype(out_dtype or x.dtype)
