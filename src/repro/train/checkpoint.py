"""Checkpointing: atomic, async-capable, elastic-restorable.

Layout: <dir>/step_<k>/ { manifest.json, arrays.npz }. Writes go to a temp
directory and are renamed into place (a crash mid-save never corrupts the
latest checkpoint). Restore can target a *different* mesh/sharding than the
save (elastic scaling): arrays are re-device_put against the shardings of
the provided abstract target tree.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, meta: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomic synchronous save. Returns the checkpoint path."""
    root = pathlib.Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=root, prefix=".tmp_save_"))
    try:
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {"step": step, "keys": sorted(flat), **(meta or {})}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        final = root / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(root, keep)
    return str(final)


class AsyncSaver:
    """Overlaps checkpoint I/O with the next training steps."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, ckpt_dir: str, step: int, tree: Any, **kw) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            self.last_path = save(ckpt_dir, step, host_tree, **kw)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure/shardings of ``target`` (arrays or
    ShapeDtypeStructs). Elastic: target shardings may differ from the ones
    the checkpoint was written under."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    blob = np.load(path / "arrays.npz")
    paths_leaves = jax.tree_util.tree_leaves_with_path(target)
    out = []
    for kp, leaf in paths_leaves:
        key = jax.tree_util.keystr(kp)
        arr = blob[key]
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and not callable(sharding):
            out.append(jax.device_put(arr.astype(leaf.dtype), sharding))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out)


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    if step is None:
        step = latest_step(ckpt_dir)
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}" / "manifest.json"
    return json.loads(path.read_text())


def _gc(root: pathlib.Path, keep: int) -> None:
    steps = sorted(root.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
