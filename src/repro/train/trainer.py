"""Trainer: LoRA fine-tuning loop with checkpoint/restart fault tolerance,
straggler monitoring, deterministic resumable data, and async checkpoints.

The restart path is the paper's deployment story at fleet scale: frozen
base weights are write-once (load from the pretrained artifact), so a
restart only restores the LoRA adapters + optimizer moments + step counter
— megabytes, not the hundreds of GB a full-FT restart would move.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lora as lora_lib
from repro.data.pipeline import ShardInfo
from repro.dist.fault import FaultCoordinator, RestartPolicy
from repro.models.transformer import ExecConfig, init_params
from repro.optim import adamw
from repro.train import checkpoint as ckpt_lib
from repro.train.steps import TrainHParams, make_train_step


@dataclass
class TrainerConfig:
    seq_len: int = 128
    global_batch: int = 8
    steps: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    keep_ckpts: int = 3
    hparams: TrainHParams = field(default_factory=TrainHParams)
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig, dataset, *,
                 exec_cfg: ExecConfig = ExecConfig(), params=None,
                 fault: Optional[FaultCoordinator] = None,
                 step_hook: Optional[Callable[[int], None]] = None):
        self.cfg, self.tc, self.dataset = cfg, tc, dataset
        self.exec_cfg = exec_cfg
        key = jax.random.PRNGKey(tc.seed)
        self.params = params if params is not None else init_params(cfg, key)
        self.lora = lora_lib.init_lora_params(cfg, jax.random.fold_in(key, 1))
        self.opt_state = adamw.init(self.lora)
        self.step = 0
        self.metrics_log: List[Dict[str, float]] = []
        self.fault = fault or FaultCoordinator(RestartPolicy())
        self.saver = ckpt_lib.AsyncSaver()
        self._step_fn = jax.jit(make_train_step(cfg, exec_cfg, tc.hparams))
        self._step_hook = step_hook  # test injection point (failures/delays)

    # ------------------------------------------------------------------
    def _batch(self, step: int):
        b = self.dataset.batch(step, self.tc.global_batch, self.tc.seq_len,
                               ShardInfo())
        return {k: jnp.asarray(v) for k, v in b.items()}

    def train_state(self):
        return {"lora": self.lora, "opt": self.opt_state._asdict(),
                "step": jnp.asarray(self.step)}

    def _load_state(self, state):
        self.lora = state["lora"]
        self.opt_state = adamw.AdamWState(**state["opt"])
        self.step = int(state["step"])

    def save_ckpt(self, sync: bool = False) -> None:
        if not self.tc.ckpt_dir:
            return
        state = self.train_state()
        if sync:
            ckpt_lib.save(self.tc.ckpt_dir, self.step, state,
                          keep=self.tc.keep_ckpts)
        else:
            self.saver.save(self.tc.ckpt_dir, self.step, state,
                            keep=self.tc.keep_ckpts)

    def maybe_restore(self) -> bool:
        if not self.tc.ckpt_dir:
            return False
        last = ckpt_lib.latest_step(self.tc.ckpt_dir)
        if last is None:
            return False
        state = ckpt_lib.restore(self.tc.ckpt_dir, self.train_state(), last)
        self._load_state(state)
        return True

    # ------------------------------------------------------------------
    def run(self) -> List[Dict[str, float]]:
        rng = jax.random.PRNGKey(self.tc.seed + 17)
        while self.step < self.tc.steps:
            if self._step_hook:
                self._step_hook(self.step)
            t0 = time.time()
            batch = self._batch(self.step)
            self.lora, self.opt_state, m = self._step_fn(
                self.params, self.lora, self.opt_state, batch,
                jax.random.fold_in(rng, self.step))
            loss = float(m["loss"])
            dt = time.time() - t0
            self.fault.on_step(self.step, dt)
            self.step += 1
            rec = {"step": self.step, "loss": loss, "sec": dt,
                   "grad_norm": float(m.get("grad_norm", np.nan))}
            self.metrics_log.append(rec)
            if self.step % self.tc.log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if self.tc.ckpt_dir and self.step % self.tc.ckpt_every == 0:
                self.save_ckpt()
        self.saver.wait()
        return self.metrics_log

    def run_with_restarts(self) -> List[Dict[str, float]]:
        """Fault-tolerant driver: on any step failure, restore the last
        checkpoint and continue (bounded by the restart policy)."""
        while True:
            try:
                return self.run()
            except Exception as exc:  # noqa: BLE001 — anything kills a step
                self.saver.wait()
                if not self.fault.should_restart(exc):
                    raise
                restored = self.maybe_restore()
                print(f"[fault] restart #{self.fault.restarts} after "
                      f"{type(exc).__name__}; restored={restored} "
                      f"at step {self.step}")
