"""Jittable step functions: train (LoRA fine-tune), prefill, decode.

These are the functions the multi-pod dry-run lowers and the trainer /
serving engine execute. Gradient accumulation runs as a microbatch scan
inside the step (the PipeLayer-style batch pipeline the paper inherits);
only the LoRA accumulator is carried — base weights never have gradients.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.transformer import ExecConfig
from repro.optim import adamw

Array = jax.Array


@dataclass(frozen=True)
class TrainHParams:
    microbatches: int = 1
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    full_finetune: bool = False   # paper mode is PEFT (LoRA-only)


def _split_micro(batch: Dict[str, Array], n: int) -> Dict[str, Array]:
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                        batch)


def make_loss_fn(cfg: ModelConfig, ec: ExecConfig):
    def loss_fn(lora, params, micro, rng):
        inputs = ({"tokens": micro["tokens"]} if "tokens" in micro
                  else {"embeds": micro["embeds"]})
        logits, _, aux = tfm.forward(cfg, params, inputs, lora=lora,
                                     mode="train", exec_cfg=ec, rng=rng)
        loss, metrics = tfm.lm_loss(cfg, logits, micro["labels"],
                                    micro.get("mask"))
        return loss, {**metrics, "lb_loss": aux["lb_loss"]}
    return loss_fn


def make_train_step(cfg: ModelConfig, ec: ExecConfig, hp: TrainHParams
                    ) -> Callable:
    """(params, lora, opt_state, batch, rng) ->
    (lora, opt_state, metrics). ``batch``: tokens/embeds (B, T), labels."""
    loss_fn = make_loss_fn(cfg, ec)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, lora, opt_state, batch, rng):
        n = hp.microbatches
        if n > 1:
            micro = _split_micro(batch, n)

            def mb_body(carry, xs):
                gacc, lacc = carry
                mb, i = xs
                (loss, mx), g = grad_fn(lora, params, mb,
                                        jax.random.fold_in(rng, i))
                gacc = jax.tree.map(lambda a, b: a + b, gacc, g)
                return (gacc, lacc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), lora)
            (gsum, lsum), _ = jax.lax.scan(
                mb_body, (g0, jnp.zeros((), jnp.float32)),
                (micro, jnp.arange(n)))
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
            metrics: Dict[str, Array] = {}
        else:
            (loss, metrics), grads = grad_fn(lora, params, batch, rng)
        new_lora, new_opt, om = adamw.apply_updates(hp.adamw, lora, grads,
                                                    opt_state)
        return new_lora, new_opt, {"loss": loss, **metrics, **om}

    return step


def make_prefill_step(cfg: ModelConfig, ec: ExecConfig,
                      cache_len: Optional[int] = None) -> Callable:
    """(params, lora, inputs, positions) -> (last_logits, cache)."""
    def step(params, lora, inputs, positions=None):
        logits, cache, _ = tfm.forward(
            cfg, params, inputs, lora=lora, positions=positions,
            mode="prefill", prefill_cache_len=cache_len, exec_cfg=ec)
        return logits[:, -1, :], cache
    return step


def make_decode_step(cfg: ModelConfig, ec: ExecConfig) -> Callable:
    """(params, lora, cache, inputs[, adapter_idx]) -> (logits (B,V), cache)."""
    def step(params, lora, cache, inputs, adapter_idx=None):
        logits, new_cache, _ = tfm.forward(
            cfg, params, inputs, lora=lora, cache=cache, mode="decode",
            exec_cfg=ec, adapter_idx=adapter_idx)
        return logits[:, -1, :], new_cache
    return step
