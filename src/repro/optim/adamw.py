"""AdamW with trainable-mask support (pure JAX, no optax dependency).

In PEFT mode the optimizer only ever sees the LoRA tree — the frozen base
never has gradients, moments, or updates (the NVM-endurance invariant of the
paper, repaid here as zero optimizer state + zero gradient traffic for
~99.5% of parameters)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    schedule: Optional[Callable[[Array], Array]] = None  # step -> lr scale


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves, jnp.zeros((), jnp.float32)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState
                  ) -> Tuple[Any, AdamWState, Dict[str, Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule is not None:
        lr = lr * cfg.schedule(step)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}


def warmup_cosine(warmup: int, total: int, floor: float = 0.1
                  ) -> Callable[[Array], Array]:
    def sched(step: Array) -> Array:
        s = step.astype(jnp.float32)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return w * cos
    return sched
