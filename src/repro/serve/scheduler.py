"""Page-occupancy scheduler for the paged serving engine.

Admission, growth, and preemption are all decided by page availability —
not slot count. A request is admitted when the pool can hold its prompt
plus one decode token; it grows page-by-page as it decodes; when the pool
runs dry the scheduler first reclaims prefix-cache pages (via the
``reclaim`` hook — only refcount-1 pages nobody is actively serving from),
then preempts the youngest running request (pages decref'd, request
requeued for recompute-style resume), which keeps the oldest requests
making progress — no deadlock, no livelock.

Prefix sharing changes the lifetime model of every page: a slot's block
table may map pages co-held by other slots and/or the prefix index, so
``release`` decrefs rather than frees, preemption accounting reports pages
ACTUALLY reclaimed (a victim whose pages are all shared frees ~nothing and
must not count toward admission headroom), and any page a slot is about to
write while others still hold it is forked copy-on-write: ``ensure``
swaps in a fresh page and queues a device-side copy (``pending_forks``)
that the engine executes before its next mixed step.

Under tensor parallelism (``ParallelConfig(tp=N)``) none of this changes:
the scheduler is pure host-side numpy state — block tables, refcounts,
preemption/CoW bookkeeping — replicated by construction, while only the
page *contents* (the pool's head_dim axis) are sharded across devices.
Page ids mean the same thing on every shard, so admission, preemption,
CoW forks, and rollback cursors are tp-invariant.

None of the scheduler's choices can change WHAT the model emits: the
engine routes MoE tokens through the dropless dispatch, so chunk widths,
preemption/resume points, and batch composition are a pure
performance/memory knob — a request's greedy tokens are identical no
matter how this scheduler slices its prompt.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.kvcache import PageAllocator, PagedLayout


@dataclass
class SlotState:
    """Engine-side bookkeeping for one occupied decode slot."""
    req: object                       # serve.api.Request
    pages: List[int] = field(default_factory=list)
    fill_len: int = 0                 # tokens already written to the cache
    admitted_tick: int = 0            # for youngest-first preemption
    shared_tokens: int = 0            # prefix-cache tokens mapped at admit


class PageScheduler:
    """Tracks the shared pool, per-slot block tables, and request lengths."""

    def __init__(self, layout: PagedLayout, max_len: int,
                 reclaim: Optional[Callable[[int], int]] = None):
        self.layout = layout
        self.max_len = max_len
        self.max_blocks = layout.blocks_for(max_len)
        self.alloc = PageAllocator(layout.num_pages)
        self.tables = np.full((layout.max_slots, self.max_blocks), -1,
                              np.int32)
        self.lens = np.zeros(layout.max_slots, np.int32)
        self.slots: List[Optional[SlotState]] = [None] * layout.max_slots
        self.reclaim = reclaim            # prefix-index eviction hook
        self.preemptions = 0
        self.peak_pages = 0
        self.reclaimed_pages = 0          # pages ACTUALLY freed by preemption
        self.rolled_back_pages = 0        # pages freed by spec-decode rollback
        self.recurrent_rollbacks = 0      # cursor rewinds paired with a
        #                                   per-slot recurrent-state restore
        self.cow_forks = 0
        self.pending_forks: List[Tuple[int, int, int]] = []  # (slot, src, dst)
        self.evicted: List[object] = []   # preempted requests to requeue

    # ------------------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Pool alloc with one prefix-cache reclaim retry when dry."""
        pages = self.alloc.alloc(n)
        if pages is None and self.reclaim is not None:
            self.reclaim(n - self.alloc.free_pages)
            pages = self.alloc.alloc(n)
        if pages is not None:
            self.peak_pages = max(self.peak_pages, self.alloc.used_pages)
        return pages

    def _grow(self, slot: int, new_len: int) -> bool:
        """Ensure the slot's table covers ``new_len`` tokens (all-or-nothing)."""
        st = self.slots[slot]
        need = self.layout.blocks_for(new_len) - len(st.pages)
        if need <= 0:
            return True
        pages = self._alloc(need)
        if pages is None:
            return False
        base = len(st.pages)
        st.pages.extend(pages)
        self.tables[slot, base:base + len(pages)] = pages
        return True

    def admit(self, req, prompt_len: int, tick: int,
              shared: Optional[Tuple[int, List[int]]] = None) -> Optional[int]:
        """Place a request if a slot and its prompt's pages are available.

        ``shared`` = (matched_tokens, pages) from the prefix index: the
        matched pages are mapped (and incref'd) into the head of the block
        table, the slot's length starts at ``matched_tokens`` so prefill
        resumes at the first unshared token, and only the remainder is
        allocated fresh (all-or-nothing; a failed remainder releases the
        shared refs too)."""
        slot = self.free_slot()
        if slot is None:
            return None
        if prompt_len + 1 > self.max_len:
            raise ValueError(
                f"prompt of {prompt_len} tokens exceeds max_len={self.max_len}")
        matched, spages = shared if shared else (0, [])
        st = SlotState(req=req, admitted_tick=tick, shared_tokens=matched)
        self.slots[slot] = st
        for p in spages:
            self.alloc.incref(p)           # before any reclaim can run
        st.pages = list(spages)
        self.tables[slot, :len(spages)] = spages
        self.lens[slot] = matched
        if not self._grow(slot, prompt_len + 1):
            self.release(slot)
            return None
        return slot

    def ensure(self, slot: int, new_len: int,
               protect: Sequence[int] = ()) -> bool:
        """Grow a slot and fork any shared page it is about to write,
        preempting younger slots if the pool is dry.

        Write range is [lens[slot], new_len): a page there with allocator
        refcount > 1 is co-held (another slot and/or the prefix index), so
        the slot gets a fresh page, a device copy is queued in
        ``pending_forks``, and the old page is decref'd — copy-on-write at
        the first divergent write.

        Returns False when the slot itself had to be preempted — either it
        was the youngest, or its growth can never fit the pool (checked
        upfront so a doomed request evicts no bystanders)."""
        if self.layout.blocks_for(new_len) > self.layout.num_pages:
            self.preempt(slot)
            return False
        while not self._grow(slot, new_len):
            victim = self.youngest(exclude=protect)
            if victim is None or victim == slot:
                self.preempt(slot)
                return False
            self.preempt(victim)
        st = self.slots[slot]
        P = self.layout.page_size
        for col in range(int(self.lens[slot]) // P,
                         self.layout.blocks_for(new_len)):
            pg = st.pages[col]
            if self.alloc.refcount(pg) <= 1:
                continue
            got = self._alloc(1)
            while got is None:
                victim = self.youngest(exclude=protect)
                if victim is None or victim == slot:
                    self.preempt(slot)
                    return False
                self.preempt(victim)
                got = self._alloc(1)
            new = got[0]
            st.pages[col] = new
            self.tables[slot, col] = new
            self.alloc.decref(pg)
            self.cow_forks += 1
            self.pending_forks.append((slot, pg, new))
        return True

    def rollback(self, slot: int, new_len: int, *,
                 recurrent: bool = False) -> int:
        """Set a slot's write cursor to ``new_len`` tokens and release
        pages now wholly past it. One call settles a speculative-decode
        tick: the cursor advances over accepted tokens and rolls back
        over rejected ones (``new_len`` may exceed or undershoot the
        pre-step length; it must stay within the pages already granted).

        ``recurrent=True`` marks a rewind issued in lockstep with a
        per-slot recurrent-state restore (``SlotStateArena``): the engine
        rewinds all the way to the pre-chunk length and replays the
        accepted tokens as a resumed prefill chunk, because ring/Mamba/
        RWKV state cannot be partially rewound. Counted separately so
        stats can attribute the extra prefill work.

        Composition with sharing: pages in the rejected range were either
        freshly allocated this tick or CoW-forked by ``ensure`` before the
        speculative write, so dropping this slot's ref can never corrupt a
        co-holder — ``release_tail`` frees only refcount-1 pages. Stale KV
        past the cursor is invisible (attend masks >= lens + chunk_lens)
        and is rewritten before it ever re-enters the visible range.
        Returns pages ACTUALLY freed."""
        st = self.slots[slot]
        assert st is not None, f"rollback of empty slot {slot}"
        assert new_len > 0, new_len
        keep = self.layout.blocks_for(new_len)
        freed = self.alloc.release_tail(st.pages, keep)
        self.tables[slot, keep:] = -1
        self.lens[slot] = new_len
        self.rolled_back_pages += freed
        if recurrent:
            self.recurrent_rollbacks += 1
        return freed

    def take_forks(self) -> List[Tuple[int, int, int]]:
        """Drain queued CoW copies (slot, src, dst). Forks whose slot was
        preempted after queuing are already dropped by ``release``."""
        out, self.pending_forks = self.pending_forks, []
        return out

    def youngest(self, exclude: Sequence[int] = ()) -> Optional[int]:
        cands = [i for i in self.active() if i not in exclude]
        if not cands:
            return None
        return max(cands, key=lambda i: self.slots[i].admitted_tick)

    def preempt(self, slot: int) -> int:
        """Recycle the slot's pages; the request resumes by recompute.
        Returns pages ACTUALLY freed — decref'ing shared pages reclaims
        nothing, so callers retrying allocation must not assume headroom."""
        req = self.slots[slot].req
        freed = self.release(slot)
        self.preemptions += 1
        self.reclaimed_pages += freed
        self.evicted.append(req)
        return freed

    def drain_evicted(self) -> List[object]:
        out, self.evicted = self.evicted, []
        return out

    def release(self, slot: int) -> int:
        """Decref the slot's pages (freeing refcount-1 ones); returns the
        count actually freed."""
        st = self.slots[slot]
        freed = 0
        if st is not None and st.pages:
            freed = self.alloc.free(st.pages)
        if st is not None and self.pending_forks:
            self.pending_forks = [f for f in self.pending_forks
                                  if f[0] != slot]
        self.tables[slot, :] = -1
        self.lens[slot] = 0
        self.slots[slot] = None
        return freed

    # ------------------------------------------------------------------
    def blocks_in_use(self, slots: Sequence[int], chunk: np.ndarray) -> int:
        """Widest block-table prefix any of ``slots`` needs this tick."""
        nb = 1
        for i in slots:
            nb = max(nb, self.layout.blocks_for(int(self.lens[i] + chunk[i])))
        return nb

    def occupancy(self) -> Dict[str, int]:
        return {"used_pages": self.alloc.used_pages,
                "free_pages": self.alloc.free_pages,
                "shared_pages": self.alloc.shared_pages,
                "peak_pages": self.peak_pages,
                "preemptions": self.preemptions,
                "reclaimed_pages": self.reclaimed_pages,
                "rolled_back_pages": self.rolled_back_pages,
                "recurrent_rollbacks": self.recurrent_rollbacks,
                "cow_forks": self.cow_forks}


def bucketize(n: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= n (buckets sorted ascending; last is the cap)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def power_buckets(cap: int, floor: int = 1) -> Tuple[int, ...]:
    """(floor, ..., powers of two, ..., cap) — O(log cap) distinct widths."""
    out = {floor, cap}
    b = floor
    while b < cap:
        b *= 2
        out.add(min(b, cap))
    return tuple(sorted(out))
