"""Page-occupancy scheduler for the paged serving engine.

Admission, growth, and preemption are all decided by page availability —
not slot count. A request is admitted when the pool can hold its prompt
plus one decode token; it grows page-by-page as it decodes; when the pool
runs dry the youngest running request is preempted (pages recycled, request
requeued for recompute-style resume), which keeps the oldest requests
making progress — no deadlock, no livelock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.kvcache import PageAllocator, PagedLayout


@dataclass
class SlotState:
    """Engine-side bookkeeping for one occupied decode slot."""
    req: object                       # serve.engine.Request
    pages: List[int] = field(default_factory=list)
    fill_len: int = 0                 # tokens already written to the cache
    admitted_tick: int = 0            # for youngest-first preemption


class PageScheduler:
    """Tracks the shared pool, per-slot block tables, and request lengths."""

    def __init__(self, layout: PagedLayout, max_len: int):
        self.layout = layout
        self.max_len = max_len
        self.max_blocks = layout.blocks_for(max_len)
        self.alloc = PageAllocator(layout.num_pages)
        self.tables = np.full((layout.max_slots, self.max_blocks), -1,
                              np.int32)
        self.lens = np.zeros(layout.max_slots, np.int32)
        self.slots: List[Optional[SlotState]] = [None] * layout.max_slots
        self.preemptions = 0
        self.peak_pages = 0
        self.evicted: List[object] = []   # preempted requests to requeue

    # ------------------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _grow(self, slot: int, new_len: int) -> bool:
        """Ensure the slot's table covers ``new_len`` tokens (all-or-nothing)."""
        st = self.slots[slot]
        need = self.layout.blocks_for(new_len) - len(st.pages)
        if need <= 0:
            return True
        pages = self.alloc.alloc(need)
        if pages is None:
            return False
        base = len(st.pages)
        st.pages.extend(pages)
        self.tables[slot, base:base + len(pages)] = pages
        self.peak_pages = max(self.peak_pages, self.alloc.used_pages)
        return True

    def admit(self, req, prompt_len: int, tick: int) -> Optional[int]:
        """Place a request if a slot and its prompt's pages are available."""
        slot = self.free_slot()
        if slot is None:
            return None
        if prompt_len + 1 > self.max_len:
            raise ValueError(
                f"prompt of {prompt_len} tokens exceeds max_len={self.max_len}")
        self.slots[slot] = SlotState(req=req, admitted_tick=tick)
        self.lens[slot] = 0
        if not self._grow(slot, prompt_len + 1):
            self.release(slot)
            return None
        return slot

    def ensure(self, slot: int, new_len: int,
               protect: Sequence[int] = ()) -> bool:
        """Grow a slot, preempting younger slots if the pool is dry.

        Returns False when the slot itself had to be preempted — either it
        was the youngest, or its growth can never fit the pool (checked
        upfront so a doomed request evicts no bystanders)."""
        if self.layout.blocks_for(new_len) > self.layout.num_pages:
            self.preempt(slot)
            return False
        while not self._grow(slot, new_len):
            victim = self.youngest(exclude=protect)
            if victim is None or victim == slot:
                self.preempt(slot)
                return False
            self.preempt(victim)
        return True

    def youngest(self, exclude: Sequence[int] = ()) -> Optional[int]:
        cands = [i for i in self.active() if i not in exclude]
        if not cands:
            return None
        return max(cands, key=lambda i: self.slots[i].admitted_tick)

    def preempt(self, slot: int) -> object:
        """Recycle the slot's pages; the request resumes by recompute."""
        req = self.slots[slot].req
        self.release(slot)
        self.preemptions += 1
        self.evicted.append(req)
        return req

    def drain_evicted(self) -> List[object]:
        out, self.evicted = self.evicted, []
        return out

    def release(self, slot: int) -> None:
        st = self.slots[slot]
        if st is not None and st.pages:
            self.alloc.free(st.pages)
        self.tables[slot, :] = -1
        self.lens[slot] = 0
        self.slots[slot] = None

    # ------------------------------------------------------------------
    def blocks_in_use(self, slots: Sequence[int], chunk: np.ndarray) -> int:
        """Widest block-table prefix any of ``slots`` needs this tick."""
        nb = 1
        for i in slots:
            nb = max(nb, self.layout.blocks_for(int(self.lens[i] + chunk[i])))
        return nb

    def occupancy(self) -> Dict[str, int]:
        return {"used_pages": self.alloc.used_pages,
                "free_pages": self.alloc.free_pages,
                "peak_pages": self.peak_pages,
                "preemptions": self.preemptions}


def bucketize(n: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= n (buckets sorted ascending; last is the cap)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def power_buckets(cap: int, floor: int = 1) -> Tuple[int, ...]:
    """(floor, ..., powers of two, ..., cap) — O(log cap) distinct widths."""
    out = {floor, cap}
    b = floor
    while b < cap:
        b *= 2
        out.add(min(b, cap))
    return tuple(sorted(out))
