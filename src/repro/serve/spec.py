"""Speculative decoding over the paged mixed step.

Draft-and-verify decoding (Leviathan/Chen-style) turns memory-bound
decode steps into compute-dense verification — the regime the
systolic/crossbar kernels are built for, and the inference-acceleration
half of the paper's quantization+acceleration story: a cheap drafter
guesses up to ``k`` tokens per slot per tick, the target model scores
all of them in ONE invocation of the existing bucketed mixed step (the
draft enters as a ragged decode-chunk ``[t0, d1..dm]``, so compile count
stays O(chunk-buckets x table-buckets)), and rejected positions rewind
the slot's paged-KV write cursor (``PageScheduler.rollback``).

Two drafters, both DELIBERATELY deterministic:

  * ``NGramDrafter`` — model-free prompt-lookup: propose the continuation
    of the most recent earlier occurrence of the stream's longest
    matching suffix n-gram. Free; shines on repetitive / retrieval-heavy
    streams.
  * ``QuantSelfDrafter`` — the target model run with
    ``quantize_params``-compressed weights (the paper's crossbar MnFm
    scheme doing double duty as the draft model) over a short relative-
    position context window, greedy-unrolled ``k`` steps in one jit.

Determinism is what keeps the acceptance rule exact AND cheap: a
deterministic drafter is a point-mass proposal ``q = delta_d``, so
rejection sampling accepts ``d`` with probability ``min(1, p(d))`` and
on rejection draws the correction from exactly ``p`` with ``d`` masked
out and renormalized — the emitted stream is distributed as the target
model's, with no need to ship full draft distributions around. At
temperature 0 this degenerates to greedy exact-match with an
argmax correction, making spec-on output TOKEN-IDENTICAL to plain
greedy decoding (the property CI asserts against the dense oracle).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Protocol, Sequence, Set, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import sample_tokens

Array = jax.Array

_EMPTY = np.empty(0, np.int32)


@dataclass(frozen=True)
class SpecConfig:
    """Knobs for speculative decoding (``make_engine(..., spec=...)``).

    ``k`` trades drafter cost + verify width against steps saved: the
    expected tokens/tick is ``E[accepted] + 1``, so raise ``k`` while the
    accept rate stays high (repetitive traffic), lower it (or stick with
    the free n-gram drafter) when drafts rarely survive verification."""
    k: int = 4                     # max draft tokens per slot per tick
    drafter: str = "ngram"         # "ngram" | "selfdraft"
    # n-gram drafter: longest..shortest suffix length to look up
    ngram_max: int = 3
    ngram_min: int = 1
    # quantized self-draft: MnFm bits, crossbar block, context window
    draft_bits: int = 4
    draft_block: int = 128
    draft_ctx: int = 64
    draft_min_size: int = 1        # quantize every >=2D weight by default


# ---------------------------------------------------------------------------
# Acceptance rule
# ---------------------------------------------------------------------------


def verify_accept(logits: Array, tokens: Array, draft_lens: Array,
                  temps: Array, rng) -> Tuple[Array, Array]:
    """Score one verified chunk per row; decide accepts and the final token.

    logits (B, C, V) — target logits for the row's chunk
    tokens (B, C)    — chunk row ``[t0, d1..dm, pad]``: the last emitted
                       token followed by ``draft_lens[b] == m`` draft tokens
    draft_lens (B,)  — m (0 = plain decode row: no drafts, just sample)
    temps (B,)       — per-row temperature (0 = greedy)

    The distribution at chunk index ``j`` scores the draft at ``j+1``:
    greedy rows accept ``d_{j+1}`` iff it equals ``argmax(logits[:, j])``;
    temperature rows accept with probability ``p_j(d_{j+1})`` (exact
    rejection sampling for a point-mass proposal). After the first
    rejection — or after all m drafts survive — ONE more token is drawn
    from the target distribution at that index (with the rejected draft
    masked out, which at temp 0 is a no-op: the argmax already differs).

    Returns (emit (B, C), n_emit (B,)): row b's first ``n_emit[b] ==
    accepted + 1`` entries of ``emit`` are the tokens to append, in order.
    Rows beyond their chunk (prefill rows, idle rows) produce garbage the
    caller ignores.
    """
    B, C, V = logits.shape
    lf = logits.astype(jnp.float32)
    rng_u, rng_fin = jax.random.split(rng)
    j = jnp.arange(C, dtype=jnp.int32)[None, :]

    greedy = jnp.argmax(lf, axis=-1)                       # (B, C)
    tl = jnp.where(temps > 0, temps, 1.0)[:, None, None]
    logp = jax.nn.log_softmax(lf / tl, axis=-1)            # (B, C, V)
    # tok_next[b, t] = tokens[b, t+1]: the draft scored by index t's dist
    tok_next = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    lp_next = jnp.take_along_axis(logp, tok_next[..., None],
                                  axis=-1)[..., 0]         # (B, C)

    def shift_right(x):
        return jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)

    # acceptance of the chunk token AT index t (a draft for t >= 1),
    # judged by the distribution at index t-1
    acc_match = tokens == shift_right(greedy)
    u = jax.random.uniform(rng_u, (B, C), minval=1e-30, maxval=1.0)
    acc_stoch = jnp.log(u) < shift_right(lp_next)
    acc = jnp.where(temps[:, None] > 0, acc_stoch, acc_match)
    is_draft = (j >= 1) & (j <= draft_lens[:, None])
    ok = jnp.where(is_draft, acc, j == 0)   # col 0 free; past drafts: stop
    run = jnp.cumprod(ok.astype(jnp.int32), axis=1)
    n_acc = jnp.sum(run, axis=1) - 1        # leading accepts, in [0, m]

    # final token at index n_acc: bonus sample when every draft survived,
    # masked-residual correction at the first rejection
    idx = n_acc[:, None, None]
    lg_fin = jnp.take_along_axis(
        lf, jnp.broadcast_to(idx, (B, 1, V)), axis=1)[:, 0]          # (B, V)
    d_rej = jnp.take_along_axis(tok_next, n_acc[:, None], axis=1)[:, 0]
    forbid = jnp.where(n_acc < draft_lens, d_rej, -1)
    fin = sample_tokens(lg_fin, temps, rng_fin, forbid=forbid)

    emit = jnp.where(j < n_acc[:, None], tok_next,
                     jnp.where(j == n_acc[:, None], fin[:, None], 0))
    return emit.astype(jnp.int32), (n_acc + 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------


@runtime_checkable
class Drafter(Protocol):
    """Pluggable draft-token source. MUST be deterministic (a point-mass
    proposal) — the acceptance rule in ``verify_accept`` relies on it."""

    def propose(self, streams: Sequence[np.ndarray],
                adapter_ids: Sequence[int], k: int) -> List[np.ndarray]:
        """Per-slot draft continuations. ``streams[i]`` is the slot's full
        token stream (prompt + generated); returns one int32 array of up
        to ``k`` proposed next tokens per slot (possibly empty)."""
        ...


class NGramDrafter:
    """Model-free prompt-lookup drafting.

    Finds the longest suffix n-gram (``max_n`` down to ``min_n``) of the
    stream that also occurs earlier, takes the MOST RECENT earlier
    occurrence, and proposes the tokens that followed it. Catches the two
    big serving patterns for free: copy-through of prompt material and
    the short generation loops small/greedy models fall into."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        assert 1 <= min_n <= max_n
        self.max_n, self.min_n = max_n, min_n

    def propose(self, streams, adapter_ids, k):
        """Vectorized across slots: all streams are right-aligned into one
        left-padded (B, W) matrix (pad = -1, outside any vocab) and every
        suffix length ``n`` is resolved for the whole batch with ONE
        sliding-window comparison — the host cost per tick is O(n_lens *
        B * W) numpy work instead of a Python loop per slot. Matches
        ``propose_ref`` exactly (longest n first; most recent hit wins)."""
        k = int(k)
        B = len(streams)
        if B == 0:
            return []
        lens = np.asarray([np.asarray(s).size for s in streams], np.int64)
        W = int(lens.max()) if B else 0
        if W < 2 or k <= 0:
            return [_EMPTY] * B
        pad = np.full((B, W), -1, np.int64)
        for b, s in enumerate(streams):
            if lens[b]:
                pad[b, W - lens[b]:] = np.asarray(s, np.int64)
        off = W - lens                       # padded index of token 0
        starts = np.full(B, -1, np.int64)    # continuation start, padded coords
        for n in range(min(self.max_n, W - 1), self.min_n - 1, -1):
            todo = (starts < 0) & (lens - 1 >= n)
            if not todo.any():
                if (starts >= 0).all():
                    break                    # every row resolved
                continue                     # shorter rows qualify at lower n
            wins = np.lib.stride_tricks.sliding_window_view(pad, n, axis=1)
            patt = pad[:, W - n:]            # the suffix n-gram per row
            eq = (wins == patt[:, None, :]).all(axis=-1)   # (B, W-n+1)
            j = np.arange(W - n + 1, dtype=np.int64)[None, :]
            # window must lie inside the row's real tokens MINUS the final
            # one (the reference searches s[:T-1])
            eq &= (j >= off[:, None]) & (j + n <= W - 1)
            hit = todo & eq.any(axis=1)
            if hit.any():
                last = (W - n) - np.argmax(eq[:, ::-1], axis=1)
                starts[hit] = last[hit] + n
        return [pad[b, starts[b]:starts[b] + k].astype(np.int32)
                if starts[b] >= 0 else _EMPTY for b in range(B)]

    def propose_ref(self, streams, adapter_ids, k):
        """The original per-slot host loop, kept as the vectorization
        oracle (tests assert propose == propose_ref on random traffic)."""
        return [self._one(np.asarray(s, np.int64), int(k)) for s in streams]

    def _one(self, s: np.ndarray, k: int) -> np.ndarray:
        T = s.size
        if T < 2 or k <= 0:
            return _EMPTY
        for n in range(min(self.max_n, T - 1), self.min_n - 1, -1):
            pat = s[T - n:]
            wins = np.lib.stride_tricks.sliding_window_view(s[:T - 1], n)
            hits = np.nonzero((wins == pat[None, :]).all(axis=1))[0]
            if hits.size:
                start = int(hits[-1]) + n
                return s[start:start + k].astype(np.int32)
        return _EMPTY


class QuantSelfDrafter:
    """Self-drafting with the paper's compression scheme as the drafter.

    The TARGET model's weights are re-quantized to ``draft_bits`` via
    ``core.quant.quantize_params`` (crossbar MnFm blocks; LoRA adapters
    ride on top unquantized) and run greedily over a truncated
    ``draft_ctx``-token context with RELATIVE positions — one jitted call
    per tick drafts ``k`` tokens for every decoding slot at once. Batch
    width is pinned to ``max_rows`` and context width is bucketized, so
    compiles stay O(log draft_ctx) regardless of traffic."""

    def __init__(self, cfg, params, adapters, spec: SpecConfig, exec_cfg,
                 max_rows: int):
        from repro.configs.base import QuantConfig
        from repro.core.quant import quantize_params
        from repro.serve.scheduler import power_buckets
        qc = QuantConfig(mha_bits=spec.draft_bits, ff_bits=spec.draft_bits,
                         block=spec.draft_block)
        self.qparams = quantize_params(params, qc,
                                       min_size=spec.draft_min_size)
        self.cfg, self.ec = cfg, exec_cfg
        self.adapters = adapters            # stacked, or None
        self.draft_ctx = spec.draft_ctx
        self.max_rows = max_rows
        self.ctx_buckets = power_buckets(spec.draft_ctx)
        self._draft = jax.jit(self._draft_fn, static_argnames=("k",))
        self._sigs: Set[Tuple[int, int]] = set()

    def _draft_fn(self, qparams, adapters, ctx, ctx_lens, adapter_idx, k):
        from repro.models import transformer as tfm
        B, W = ctx.shape
        positions = jnp.broadcast_to(jnp.arange(W, dtype=jnp.int32)[None],
                                     (B, W))
        logits, cache, _ = tfm.forward(
            self.cfg, qparams, {"tokens": ctx}, lora=adapters,
            positions=positions, mode="prefill", prefill_cache_len=W + k,
            exec_cfg=self.ec, adapter_idx=adapter_idx, chunk_lens=ctx_lens)
        last = jnp.clip(ctx_lens - 1, 0, W - 1)[:, None, None]
        lg = jnp.take_along_axis(
            logits, jnp.broadcast_to(last, (B, 1, logits.shape[-1])),
            axis=1)[:, 0]
        toks = [jnp.argmax(lg, -1).astype(jnp.int32)]
        for i in range(k - 1):
            pos = (ctx_lens + i)[:, None].astype(jnp.int32)
            lg2, cache, _ = tfm.forward(
                self.cfg, qparams, {"tokens": toks[-1][:, None]},
                lora=adapters, cache=cache, positions=pos, mode="decode",
                exec_cfg=self.ec, adapter_idx=adapter_idx)
            toks.append(jnp.argmax(lg2[:, -1], -1).astype(jnp.int32))
        return jnp.stack(toks, axis=1)      # (B, k)

    def propose(self, streams, adapter_ids, k):
        from repro.serve.scheduler import bucketize
        n = len(streams)
        if n == 0 or k <= 0:
            return [_EMPTY] * n
        assert n <= self.max_rows, (n, self.max_rows)
        tails = [np.asarray(s[-self.draft_ctx:], np.int32) for s in streams]
        Wb = bucketize(max(t.size for t in tails), self.ctx_buckets)
        ctx = np.zeros((self.max_rows, Wb), np.int32)
        lens = np.zeros(self.max_rows, np.int32)
        for i, t in enumerate(tails):
            ctx[i, :t.size] = t
            lens[i] = t.size
        aidx = None
        if self.adapters is not None:
            ai = np.zeros(self.max_rows, np.int32)
            ai[:n] = np.asarray(adapter_ids, np.int32)
            aidx = jnp.asarray(ai)
        self._sigs.add((Wb, int(k)))
        out = np.asarray(self._draft(self.qparams, self.adapters,
                                     jnp.asarray(ctx), jnp.asarray(lens),
                                     aidx, int(k)))
        return [out[i] for i in range(n)]

    def stats(self):
        return {"draft_signatures": sorted(self._sigs),
                "draft_compiles": len(self._sigs)}


def make_drafter(cfg, params, adapters, spec: SpecConfig, exec_cfg,
                 max_rows: int) -> Drafter:
    """Build the drafter named by ``spec.drafter``."""
    if spec.drafter == "ngram":
        return NGramDrafter(spec.ngram_max, spec.ngram_min)
    if spec.drafter == "selfdraft":
        return QuantSelfDrafter(cfg, params, adapters, spec, exec_cfg,
                                max_rows)
    raise ValueError(f"unknown drafter {spec.drafter!r} "
                     f"(expected 'ngram' or 'selfdraft')")
