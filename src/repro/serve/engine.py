"""Serving engines: continuous batching + multi-adapter LoRA decode.

The paper's inference story (SS V.G): the frozen base lives on-chip
(crossbar-quantized); switching tasks means swapping only LoRA adapters —
"a fraction of the pre-trained model parameters". Here that becomes
multi-tenant serving: adapters are stacked along a leading dim and every
request carries an adapter id; one batched step serves a mixed batch of
tasks (S-LoRA-style).

Two engines implement the unified ``serve.api`` surface (Request /
Completion, submit / step / drain / stats) — construct them through
``serve.api.make_engine``:

  * ``DenseServeEngine`` — the dense oracle: per-slot KV rows in a fixed
    ``max_batch x max_len`` arena, one whole-prompt prefill compile per
    distinct prompt length. Kept ONLY for equivalence testing and as the
    benchmark baseline; production serving goes through the paged engine.
  * ``PagedServeEngine`` — the production engine: full-attention KV lives
    in a shared page pool addressed by per-request block tables
    (vLLM-style); prefill runs in fixed-width chunks drawn from a small
    set of padded buckets; prefill chunks and decode steps run through ONE
    fully-jitted mixed step whose compile count is O(#chunk buckets x
    #table-width buckets) instead of O(#prompt lengths). Admission and
    eviction are decided by page occupancy (``serve.scheduler``), and the
    cache is donated through ``jax.jit(..., donate_argnums=...)`` so decode
    updates the arena in place on accelerators.

    Prompt prefixes are never recomputed or re-stored: a radix prefix
    index (``serve.prefix``) maps new requests onto already-resident
    pages, pages carry refcounts, any shared page is forked copy-on-write
    before its first divergent write, and chunked prefill resumes at the
    first unshared token. Finished requests donate their prompt pages to
    the index; pool pressure reclaims them youngest-first before any
    running request is preempted.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lora as lora_lib
from repro.core.lora import scan_period
from repro.models import kvcache, transformer as tfm
from repro.models.kvcache import PagedLayout
from repro.models.transformer import ExecConfig
from repro.serve import spec as spec_mod
from repro.serve.api import (Completion, CompileStats, EngineStats,
                             MoEStats, ParallelConfig, ParallelStats,
                             PrefixCacheStats, Request, SchedulerStats,
                             SpecStats, completion_of)
from repro.serve.prefix import PrefixIndex
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import PageScheduler, bucketize, power_buckets
from repro.serve.spec import SpecConfig


def _validate_request(req: Request, max_len: int) -> None:
    """Shared admission contract: both engines fail fast at submit."""
    if len(req.prompt) == 0:
        raise ValueError(f"request uid={req.uid}: empty prompt")
    if len(req.prompt) + 1 > max_len:
        raise ValueError(f"request uid={req.uid}: prompt of "
                         f"{len(req.prompt)} tokens exceeds "
                         f"max_len={max_len}")


# the one sampling rule, shared with the spec-decode verifier
_sample = sample_tokens


def _force_moe_dispatch(exec_cfg: ExecConfig, dispatch: str) -> ExecConfig:
    """Serving routes MoE tokens drop-free: capacity drops would make a
    request's greedy tokens depend on how its prompt was chunked,
    preempted, or batched. ``dispatch="capacity"`` is allowed only as an
    explicit baseline for benchmarking the dropless overhead."""
    if dispatch not in ("dropless", "capacity"):
        raise ValueError(f"unknown moe_dispatch {dispatch!r} "
                         "(expected 'dropless' or 'capacity')")
    return dataclasses.replace(exec_cfg, moe_dispatch=dispatch)


def _track_drops(engine, dropped) -> None:
    """Accumulate a step's MoE drop count; under dropless dispatch any
    nonzero count is an invariant violation, not a statistic."""
    d = int(np.asarray(dropped))
    engine.moe_dropped_tokens += d
    if d and engine.ec.moe_dispatch == "dropless":
        raise RuntimeError(
            f"dropless MoE dispatch dropped {d} (token, expert) "
            "assignments — the drop-free invariant is broken")


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------


class DenseServeEngine:
    """Slot-based continuous batching over a fixed dense decode arena.

    The equivalence oracle: compiles prefill per prompt length and stores
    KV at ``max_batch x max_len`` regardless of live context — use
    ``PagedServeEngine`` (via ``make_engine``) for actual serving."""

    def __init__(self, cfg: ModelConfig, params, adapters: Sequence = (), *,
                 max_batch: int = 8, max_len: int = 512,
                 exec_cfg: ExecConfig = ExecConfig(), seed: int = 0):
        self.cfg, self.params = cfg, params
        # the oracle decodes one token per row — dropless by nature — and
        # prefills whole prompts; forcing dropless dispatch makes the
        # whole-prompt pass routing-identical to any chunking of it
        self.ec = _force_moe_dispatch(exec_cfg, "dropless")
        self.max_batch, self.max_len = max_batch, max_len
        self._has_moe = any(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        self.moe_dropped_tokens = 0
        self.adapters = (lora_lib.stack_adapters(list(adapters))
                         if adapters else None)
        self.cache = kvcache.init_cache(cfg, max_batch, max_len,
                                        kv_dtype=jnp.float32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn)
        self.prefill_buckets = power_buckets(max_len)
        self._prefill_sigs: Set[int] = set()
        self._tick = 0
        self.decode_tokens = 0
        self.prefill_tokens = 0

    # ------------------------------------------------------------------
    def _adapter_idx(self):
        return jnp.asarray([r.adapter_id if r else 0 for r in self.slot_req],
                           jnp.int32)

    def _prefill_fn(self, params, adapters, cache, tokens, positions, plen,
                    slot, adapter_idx):
        """Prefill one request into its slot via repeated decode steps is
        wasteful; instead run a full forward and scatter the produced cache
        rows into the arena at ``slot``.

        Prompts arrive padded to a ``power_buckets`` width with the true
        length in ``plen`` (1,): pad tokens are masked out of attention /
        SSM state / MoE capacity via ``chunk_lens``, and the last REAL
        position's logits are gathered — one compile per bucket instead of
        one per distinct prompt length."""
        logits, req_cache, aux = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters,
            positions=positions, mode="prefill",
            prefill_cache_len=self.max_len, exec_cfg=self.ec,
            adapter_idx=adapter_idx, chunk_lens=plen)

        def merge(arena, row):
            # every cache leaf is (n_sp, B, ...): scatter the request's row
            # (B=1) into the arena at its slot
            return jax.lax.dynamic_update_slice_in_dim(
                arena, row.astype(arena.dtype), slot, axis=1)

        merged = jax.tree.map(merge, cache, req_cache)
        last = jnp.clip(plen - 1, 0, tokens.shape[1] - 1)[:, None, None]
        lg = jnp.take_along_axis(
            logits, jnp.broadcast_to(last, (1, 1, logits.shape[-1])),
            axis=1)[:, 0]
        return lg, merged, aux["moe_dropped_tokens"]

    def _decode_fn(self, params, adapters, cache, tokens, positions,
                   adapter_idx, rng, temps):
        logits, new_cache, aux = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters, cache=cache,
            positions=positions, mode="decode", exec_cfg=self.ec,
            adapter_idx=adapter_idx)
        return (_sample(logits[:, -1, :], temps, rng), new_cache,
                aux["moe_dropped_tokens"])

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        _validate_request(req, self.max_len)
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                plen = len(req.prompt)
                padded = bucketize(plen, self.prefill_buckets)
                toks = np.zeros((1, padded), np.int32)
                toks[0, :plen] = np.asarray(req.prompt, np.int32)
                pos = jnp.arange(padded, dtype=jnp.int32)[None]
                adapter_idx = (jnp.asarray([req.adapter_id], jnp.int32)
                               if self.adapters is not None else None)
                self._prefill_sigs.add(padded)
                last_logits, self.cache, dropped = self._prefill(
                    self.params, self.adapters, self.cache,
                    jnp.asarray(toks), pos,
                    jnp.asarray([plen], jnp.int32), i, adapter_idx)
                _track_drops(self, dropped)
                self._rng, rng = jax.random.split(self._rng)
                temps1 = jnp.asarray([req.temperature], jnp.float32)
                tok = int(np.asarray(_sample(last_logits, temps1, rng))[0])
                req.generated.append(tok)
                self.slot_pos[i] = plen
                self.prefill_tokens += plen

    def step(self) -> None:
        """One engine tick: admit queued requests, run one batched decode
        step for every active slot, retire finished requests."""
        self._tick += 1
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        last = [(self.slot_req[i].generated[-1]
                 if self.slot_req[i] is not None and self.slot_req[i].generated
                 else 0) for i in range(self.max_batch)]
        toks = jnp.asarray(last, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        temps = jnp.asarray([r.temperature if r else 0.0
                             for r in self.slot_req], jnp.float32)
        self._rng, rng = jax.random.split(self._rng)
        idx = self._adapter_idx() if self.adapters is not None else None
        toks_out, self.cache, dropped = self._decode(
            self.params, self.adapters, self.cache, toks, pos, idx, rng,
            temps)
        _track_drops(self, dropped)
        toks_np = np.asarray(toks_out)
        for i in active:
            req = self.slot_req[i]
            self.slot_pos[i] += 1
            self.decode_tokens += 1
            tok = int(toks_np[i])
            req.generated.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                req.finish_reason = "eos" if hit_eos else "length"
                self.finished[req.uid] = req
                self.slot_req[i] = None
                self.slot_pos[i] = 0

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished

    def drain(self, max_ticks: int = 10_000) -> Dict[int, Completion]:
        self.run_until_done(max_ticks)
        return {uid: completion_of(r) for uid, r in self.finished.items()}

    def stats(self) -> EngineStats:
        return EngineStats(
            engine="dense", ticks=self._tick,
            decode_tokens=self.decode_tokens,
            prefill_tokens=self.prefill_tokens,
            compile=CompileStats(
                prefill_signatures=tuple(sorted(self._prefill_sigs)),
                prefill_compiles=len(self._prefill_sigs)),
            moe=MoEStats(enabled=self._has_moe,
                         dispatch=self.ec.moe_dispatch,
                         dropped_tokens=self.moe_dropped_tokens),
            kv_bytes=kvcache.cache_bytes(self.cache))


# ---------------------------------------------------------------------------
# Paged engine
# ---------------------------------------------------------------------------


def _stream(req: Request) -> np.ndarray:
    """Tokens that belong in the cache: the prompt plus every generated
    token except the newest (which is the next decode input)."""
    if len(req.generated) <= 1:
        return np.asarray(req.prompt, np.int32)
    return np.concatenate([np.asarray(req.prompt, np.int32),
                           np.asarray(req.generated[:-1], np.int32)])


def _stream_len(req: Request) -> int:
    """len(_stream(req)) without materializing the concatenation."""
    return len(req.prompt) + max(0, len(req.generated) - 1)


class PagedServeEngine:
    """Continuous batching over a paged, prefix-shared KV arena with
    chunked prefill.

    Every tick runs ONE jitted mixed step over all ``max_slots`` rows:
    rows mid-prompt consume a chunk of up to ``prefill_chunk`` tokens,
    decoding rows consume their last sampled token, idle rows are masked
    out via ``chunk_lens == 0``. The step specializes only on the
    (chunk-bucket, table-width-bucket) pair, so total compiles are
    O(log max_len), independent of how many distinct prompt lengths the
    traffic contains.

    Prefix sharing: at admission the radix index maps the longest indexed
    prefix of the prompt onto resident pages (incref'd into the block
    table) and prefill resumes at the first unshared token; pages a slot
    is about to write while co-held are forked copy-on-write (a device
    page copy runs before the mixed step). Sharing is only sound when
    every layer's decode state lives in the shared pool, so it is
    auto-disabled for architectures with sliding-window / Mamba / RWKV
    layers (their per-slot ring and recurrent states cannot be shared).

    Speculative decoding runs on EVERY architecture: per-slot ring /
    Mamba / RWKV state is checkpointed inside the jitted verify step
    (``SlotStateArena.snapshot``) and select-restored per slot when any
    draft is rejected; the scheduler cursor rewinds to the pre-chunk
    length in lockstep and the accepted tokens replay as a resumed
    prefill chunk next tick (they are already part of the stream), which
    rebuilds the recurrent state token-exactly. Full-attention-only
    models keep the cheaper cursor-only partial rollback."""

    def __init__(self, cfg: ModelConfig, params, adapters: Sequence = (), *,
                 max_slots: int = 16, max_len: int = 512, page_size: int = 16,
                 num_pages: Optional[int] = None, prefill_chunk: int = 32,
                 enable_prefix_cache: bool = True,
                 spec: Optional[SpecConfig] = None,
                 parallel: Optional[ParallelConfig] = None,
                 prefix_cache_path: Optional[str] = None,
                 moe_dispatch: str = "dropless",
                 exec_cfg: ExecConfig = ExecConfig(), seed: int = 0):
        self.cfg, self.params = cfg, params
        # dropless (default): every serving row — prefill chunk, decode
        # row, spec-verify tail — routes MoE tokens drop-free, so greedy
        # tokens cannot depend on chunking/preemption/batch composition.
        # "capacity" remains constructible ONLY as a bench baseline.
        self.ec = _force_moe_dispatch(exec_cfg, moe_dispatch)
        self._has_moe = any(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        self.moe_dropped_tokens = 0
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        if num_pages is None:
            # default: half of the dense arena's footprint — mixed traffic
            # rarely keeps every slot at max_len
            num_pages = max(max_slots * (-(-max_len // page_size)) // 2,
                            -(-max_len // page_size) + 1)
        self.layout = PagedLayout(page_size=page_size, num_pages=num_pages,
                                  max_slots=max_slots)
        self.adapters = (lora_lib.stack_adapters(list(adapters))
                         if adapters else None)
        self.cache = kvcache.init_paged_cache(cfg, self.layout, max_len,
                                              kv_dtype=jnp.float32)
        self.sched = PageScheduler(self.layout, max_len)
        # prefix sharing is exact only when ALL decode state is paged —
        # any ring/recurrent layer keeps per-slot state that a prefill
        # skip would leave uncomputed
        full_attn_only = all(
            cfg.block_kind(pos) == "attn" and cfg.attn_kind(pos) == "full"
            for pos in range(scan_period(cfg)))
        self.prefix: Optional[PrefixIndex] = (
            PrefixIndex(self.sched.alloc, page_size)
            if enable_prefix_cache and full_attn_only else None)
        if self.prefix is not None:
            self.sched.reclaim = self.prefix.evict
        # per-slot ring/recurrent state: checkpointed around spec-verify
        # chunks, zeroed on slot recycle. tracked == False on
        # full-attention-only models (every method no-ops there).
        self.arena = kvcache.SlotStateArena(cfg)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._rng = jax.random.PRNGKey(seed)
        # ---- speculative decoding (off by default: spec=None keeps the
        # engine byte-identical to the non-spec configuration) ----
        if isinstance(spec, str):
            spec = SpecConfig(drafter=spec)
        self.spec: Optional[SpecConfig] = None
        self.drafter = None
        if spec is not None:
            self.spec = spec
            self.drafter = spec_mod.make_drafter(
                cfg, params, self.adapters, spec, exec_cfg, max_slots)
            self._spec_step = jax.jit(self._spec_step_fn,
                                      donate_argnums=(2,))
        # ---- tensor parallelism: placed AFTER the drafter (drafters
        # propose on host from the unsharded copies) and BEFORE the jits,
        # which trace with whatever sharder self.ec carries
        self._init_parallel(parallel)
        # verify chunks are 1 + k tokens wide — fold them into the bucket
        # ladder so spec ticks reuse the O(buckets) compile budget
        self.chunk_buckets = power_buckets(
            max(prefill_chunk, (self.spec.k + 1) if self.spec else 1))
        self.block_buckets = power_buckets(self.sched.max_blocks)
        # CoW copies are few per tick (only pages straddling a write
        # boundary can be shared) — bucket widths to keep compiles O(log)
        self.fork_buckets = power_buckets(
            max_slots * (max(prefill_chunk // page_size, 1) + 2))
        self._step = jax.jit(self._step_fn, donate_argnums=(2,))
        self._fork = jax.jit(kvcache.fork_pages, donate_argnums=(0,))
        self._signatures: Set[Tuple[int, int]] = set()
        self._tick = 0
        self.decode_tokens = 0
        self.prefill_tokens = 0
        self.prefix_hit_tokens = 0
        self.prefix_hits = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.rolled_back_tokens = 0
        self.spec_steps = 0
        # ---- prefix-cache persistence: load a saved index into the fresh
        # pool (last: the scatter must see the final, possibly sharded
        # cache). Missing file = cold start, not an error.
        self.prefix_cache_path = prefix_cache_path
        self.prefix_loaded_pages = 0
        if prefix_cache_path is not None and self.prefix is None:
            warnings.warn("prefix_cache_path ignored: the prefix cache is "
                          "disabled on this engine", stacklevel=2)
        elif (prefix_cache_path is not None
                and os.path.exists(prefix_cache_path)):
            self.cache, self.prefix_loaded_pages = self.prefix.load(
                prefix_cache_path, self.cache)

    # ------------------------------------------------------------------
    def _init_parallel(self, parallel: Optional[ParallelConfig]) -> None:
        """Shard the engine across a (1, tp) device mesh.

        Device-side state shards: params via ``dist.sharding`` rules
        (attention heads / head_dim, MoE expert slots, FFN hidden dims on
        the ``model`` axis), the paged KV pool on its head_dim axis (the
        ``paged_pool``/``kp``/``vp`` rules), activations via the sharder
        threaded through ``ExecConfig``. Host-side state — block tables,
        scheduler/allocator refcounts, CoW fork queues, rollback cursors,
        the prefix trie, drafters — is numpy and stays replicated, so
        every serving feature composes unchanged. Sharding constraints
        preserve numerics, so greedy tokens match the single-device
        engine."""
        self.parallel = parallel or ParallelConfig()
        self.mesh = None
        tp = self.parallel.tp
        if tp == 1:
            return
        if jax.device_count() < tp:
            raise ValueError(
                f"ParallelConfig(tp={tp}) needs {tp} devices; "
                f"only {jax.device_count()} available")
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import sharding as shd
        from repro.launch.mesh import make_tp_mesh
        self.mesh = make_tp_mesh(tp)
        axes = shd.axes_for(self.mesh)
        # batch (slot) dims replicate: one scheduler drives all shards
        self.ec = dataclasses.replace(
            self.ec, sharder=shd.make_sharder(self.mesh, axes, "decode",
                                              shard_batch=False))
        pshapes = jax.eval_shape(lambda: self.params)
        psh = shd.guard_divisible(
            shd.params_shardings(self.cfg, pshapes, self.mesh, axes,
                                 "decode", shard_batch=False), pshapes)
        self.params = jax.device_put(self.params, psh)
        if self.adapters is not None:
            self.adapters = jax.device_put(
                self.adapters, NamedSharding(self.mesh, P()))
        fn = shd.cache_shardings(self.cfg, self.mesh, axes,
                                 shard_batch=False)
        csh = {"layers": tuple(
            {name: fn(pos, name, leaf.shape)
             for name, leaf in entry.items()}
            for pos, entry in enumerate(self.cache["layers"]))}
        csh = shd.guard_divisible(csh, jax.eval_shape(lambda: self.cache))
        self.cache = jax.device_put(self.cache, csh)

    # ------------------------------------------------------------------
    def _step_fn(self, params, adapters, cache, tokens, lens, clens,
                 block_table, adapter_idx, rng, temps):
        B, C = tokens.shape
        positions = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        paged = {"block_table": block_table, "lens": lens,
                 "chunk_lens": clens, "page_size": self.layout.page_size}
        logits, new_cache, aux = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters, cache=cache,
            positions=positions, mode="decode", exec_cfg=self.ec,
            adapter_idx=adapter_idx, paged=paged, chunk_lens=clens)
        last = jnp.clip(clens - 1, 0, C - 1)[:, None, None]
        lg = jnp.take_along_axis(
            logits, jnp.broadcast_to(last, (B, 1, logits.shape[-1])),
            axis=1)[:, 0]
        return _sample(lg, temps, rng), new_cache, aux["moe_dropped_tokens"]

    def _spec_step_fn(self, params, adapters, cache, tokens, lens, clens,
                      draft_lens, block_table, adapter_idx, rng, temps):
        """The spec-decode verify step: the SAME mixed forward as
        ``_step_fn`` — draft tokens ride in as the ragged tail of a
        decode row's chunk, so one invocation scores up to k drafts per
        slot — followed by the acceptance rule instead of last-position
        sampling only. Kept separate so spec=None engines trace exactly
        the PR-2 step.

        Verify rows carry several real tokens that the dense reference
        decodes one-at-a-time, so their MoE routing must be lossless — a
        capacity drop inside a verify chunk would score drafts under a
        different distribution than the target model and break the
        acceptance rule's equivalence guarantee. The engine-wide dropless
        dispatch covers that for free (every row, not just verify rows,
        routes drop-free), so there is no per-row MoE carve-out left.

        Per-slot ring/recurrent state (SlotStateArena): the pre-chunk
        leaves are snapshotted before the forward and select-restored per
        slot afterwards — a slot keeps its post-chunk state only when
        every draft was accepted (the chunk's writes are then all final);
        any rejection restores the checkpoint and the host rewinds the
        cursor to the pre-chunk length (``_advance_spec``), replaying the
        accepted tokens as a resumed prefill chunk. Pool KV (kp/vp) needs
        no checkpoint: writes at position j depend only on inputs <= j,
        so the cursor alone hides the rejected suffix. On
        full-attention-only models the arena is empty and this traces
        exactly the PR-3 step."""
        B, C = tokens.shape
        positions = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        paged = {"block_table": block_table, "lens": lens,
                 "chunk_lens": clens, "page_size": self.layout.page_size}
        ckpt = self.arena.snapshot(cache)
        logits, new_cache, aux = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters, cache=cache,
            positions=positions, mode="decode", exec_cfg=self.ec,
            adapter_idx=adapter_idx, paged=paged, chunk_lens=clens)
        rng_pf, rng_v = jax.random.split(rng)
        # prefill rows still sample at their last real position
        last = jnp.clip(clens - 1, 0, C - 1)[:, None, None]
        lg = jnp.take_along_axis(
            logits, jnp.broadcast_to(last, (B, 1, logits.shape[-1])),
            axis=1)[:, 0]
        tok_last = _sample(lg, temps, rng_pf)
        emit, n_emit = spec_mod.verify_accept(logits, tokens, draft_lens,
                                              temps, rng_v)
        # keep post-chunk state for non-verify rows and full accepts
        # (n_emit == draft_lens + 1); restore the checkpoint otherwise —
        # the select on the accepted-length scalar, per slot
        keep = (draft_lens == 0) | (n_emit > draft_lens)
        new_cache = self.arena.restore(new_cache, ckpt, keep)
        return tok_last, emit, n_emit, new_cache, aux["moe_dropped_tokens"]

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        _validate_request(req, self.max_len)
        if (self.layout.blocks_for(len(req.prompt) + 1)
                > self.layout.num_pages):
            raise ValueError(
                f"request uid={req.uid}: prompt of {len(req.prompt)} tokens "
                f"needs more pages than the pool holds "
                f"({self.layout.num_pages} pages of {self.layout.page_size})")
        self.queue.append(req)

    def _pending_donor(self, req: Request, matched: int) -> bool:
        """True when an active slot still mid-prefill shares more full
        pages of this prompt than the index resolves yet — admitting now
        would duplicate prefill the donor is about to register."""
        P = self.layout.page_size
        sched = self.sched
        for i in sched.active():
            st = sched.slots[i]
            if st.req.adapter_id != req.adapter_id:
                continue
            if int(sched.lens[i]) >= _stream_len(st.req):
                continue                      # donor already decoding
            common = 0
            for a, b in zip(req.prompt, st.req.prompt):
                if int(a) != int(b):
                    break
                common += 1
            if (common // P) * P > matched:
                return True
        return False

    def _admit(self) -> None:
        fresh = []
        while self.queue:
            req = self.queue[0]
            shared = None
            if self.prefix is not None:
                stream = _stream(req)
                # always leave >= 1 token to prefill: the last stream
                # token's logits seed the next sample
                matched, spages = self.prefix.lookup(
                    req.adapter_id, stream[:_stream_len(req) - 1])
                if matched:
                    shared = (matched, spages)
                if self._pending_donor(req, matched):
                    break
            slot = self.sched.admit(req, _stream_len(req), self._tick,
                                    shared=shared)
            if slot is None:
                if not self.sched.active():
                    raise RuntimeError(
                        f"request uid={req.uid} needs more pages than the "
                        f"pool holds ({self.layout.num_pages} pages of "
                        f"{self.layout.page_size})")
                break
            self.queue.pop(0)
            fresh.append(slot)
            if shared:
                self.prefix_hit_tokens += shared[0]
                self.prefix_hits += 1
        if fresh:
            # recycled slots carry stale ring/recurrent rows (including
            # state a spec checkpoint restored for a released request) —
            # zero them through the arena so nothing leaks into the
            # fresh request
            self.cache = self.arena.reset(self.cache, fresh)

    def _run_forks(self) -> None:
        """Execute queued copy-on-write page copies (device-side) before
        the mixed step writes into the forked pages."""
        forks = [(s, d) for _, s, d in self.sched.take_forks()]
        if not forks:
            return
        width = bucketize(len(forks), self.fork_buckets)
        forks = forks + [forks[-1]] * (width - len(forks))
        src = jnp.asarray([f[0] for f in forks], jnp.int32)
        dst = jnp.asarray([f[1] for f in forks], jnp.int32)
        self.cache = self._fork(self.cache, src, dst)

    def _register_progress(self, slot: int) -> None:
        """Index every COMPLETED full prompt page of a mid-prefill slot so
        same-prefix requests admitted next tick share them immediately."""
        st = self.sched.slots[slot]
        req = st.req
        n_done = min(int(self.sched.lens[slot]), len(req.prompt)) \
            // self.layout.page_size
        if n_done:
            self.prefix.register(req.adapter_id,
                                 req.prompt[:n_done * self.layout.page_size],
                                 st.pages[:n_done], self._tick)

    def _propose_drafts(self, active: Sequence[int],
                        phase: Dict[int, str]) -> Dict[int, np.ndarray]:
        """Ask the drafter for up to k tokens per decoding slot.

        Per-slot caps keep the verified run inside both budgets: appending
        ``accepted + 1 <= cap + 1`` tokens can neither exceed the request's
        ``max_new_tokens`` nor push the cache past ``max_len - 1`` (the
        dense engine's cut-off), so finish reasons land on exactly the
        token they would under plain decode. The drafter is always called
        with the full ``spec.k`` (one jit signature); caps truncate here."""
        sched = self.sched
        cand, streams, aids, caps = [], [], [], []
        for i in active:
            if phase[i] != "decode":
                continue
            req = sched.slots[i].req
            cap = min(self.spec.k,
                      req.max_new_tokens - len(req.generated) - 1,
                      self.max_len - 2 - int(sched.lens[i]))
            if cap <= 0:
                continue
            cand.append(i)
            caps.append(cap)
            streams.append(np.concatenate([
                np.asarray(req.prompt, np.int32),
                np.asarray(req.generated, np.int32)]))
            aids.append(req.adapter_id)
        if not cand:
            return {}
        props = self.drafter.propose(streams, aids, self.spec.k)
        return {i: np.asarray(d, np.int32)[:cap]
                for i, cap, d in zip(cand, caps, props)
                if np.asarray(d).size}

    def _advance_spec(self, i: int, m: int, emit_row: np.ndarray,
                      n: int) -> None:
        """Settle one decode slot after a verified tick: move the write
        cursor to ``L + accepted + 1``, free pages past it (rejected
        drafts), and append the emitted tokens in dense order — eos /
        max_new / length-cap checks fire on exactly the token they would
        under one-at-a-time decode.

        On architectures with per-slot ring/recurrent state a rejection
        cannot be settled by a partial rewind: the jitted step already
        restored this slot's state to the pre-chunk checkpoint, so the
        cursor rewinds all the way to ``L`` and the ``n`` accepted tokens
        re-enter next tick as a resumed prefill chunk (they are already
        in the stream: ``[generated[-1], emit_0..emit_{n-2}]``), which
        rebuilds the recurrent state token-exactly. Cost per rejection:
        one replayed ragged chunk of ``n <= k + 1`` tokens."""
        sched = self.sched
        st = sched.slots[i]
        req = st.req
        L = int(sched.lens[i])
        self.accepted_tokens += n - 1
        self.rolled_back_tokens += m - (n - 1)
        if m and n <= m and self.arena.tracked:
            sched.rollback(i, L, recurrent=True)
        elif m:
            sched.rollback(i, L + n)
        else:
            sched.lens[i] = L + n           # plain decode row: n == 1
        done = None
        for t in range(n):
            tok = int(emit_row[t])
            req.generated.append(tok)
            self.decode_tokens += 1
            if req.eos_id is not None and tok == req.eos_id:
                done = "eos"
                break
            if len(req.generated) >= req.max_new_tokens:
                done = "length"
                break
        # cap on the SETTLED position L + n, not sched.lens[i] — a
        # recurrent rollback rewinds lens to L for the replay, but the
        # request has still consumed L + n cache positions
        if done is None and L + n >= self.max_len - 1:
            done = "length"
        if done is not None:
            req.done = True
            req.finish_reason = done
            self.finished[req.uid] = req
            if (self.prefix is not None
                    and len(req.prompt) % self.layout.page_size):
                self.prefix.register_tail(
                    req.adapter_id, req.prompt,
                    st.pages[len(req.prompt) // self.layout.page_size],
                    self._tick)
            sched.release(i)

    def step(self) -> None:
        """One tick: admit, resolve CoW forks, build a mixed ragged chunk,
        run the jitted step, advance lengths, sample/retire."""
        self._tick += 1
        self._admit()
        sched = self.sched
        active = sched.active()
        if not active:
            return
        B = self.layout.max_slots

        # ---- per-slot chunk widths
        want = np.zeros(B, np.int32)
        phase: Dict[int, str] = {}
        for i in active:
            st = sched.slots[i]
            remaining = _stream_len(st.req) - int(sched.lens[i])
            if remaining > 0:
                want[i] = min(remaining, self.prefill_chunk)
                phase[i] = "prefill"
            else:
                want[i] = 1
                phase[i] = "decode"

        # ---- speculative drafts widen decode rows to 1 + m tokens
        drafts: Dict[int, np.ndarray] = {}
        if self.spec is not None:
            drafts = self._propose_drafts(active, phase)
            for i, d in drafts.items():
                want[i] = 1 + d.size

        # ---- page capacity (oldest slots are protected; pool pressure
        # reclaims prefix-cache pages first, then preempts the youngest,
        # which requeues for recompute). ensure() also forks any shared
        # page inside this tick's write range (copy-on-write).
        protected: List[int] = []
        for i in sorted(active,
                        key=lambda j: sched.slots[j].admitted_tick):
            if sched.slots[i] is None:      # preempted as someone's victim
                continue
            sched.ensure(i, int(sched.lens[i]) + int(want[i]),
                         protect=protected + [i])
            if sched.slots[i] is not None:
                protected.append(i)
        for req in reversed(sched.drain_evicted()):
            if (self.layout.blocks_for(_stream_len(req) + 1)
                    > self.layout.num_pages):
                # the stream has outgrown the entire pool — retire at
                # capacity, mirroring the dense engine's max_len cut-off
                req.done = True
                req.finish_reason = "capacity"
                self.finished[req.uid] = req
            else:
                self.queue.insert(0, req)
        active = sched.active()
        if not active:
            return
        self._run_forks()

        # ---- assemble the mixed batch
        C = bucketize(int(max(want[i] for i in active)), self.chunk_buckets)
        tokens = np.zeros((B, C), np.int32)
        clens = np.zeros(B, np.int32)
        dlens = np.zeros(B, np.int32)
        for i in active:
            st = sched.slots[i]
            if phase[i] == "prefill":
                stream = _stream(st.req)
                L = int(sched.lens[i])
                chunk = stream[L:L + int(want[i])]
                tokens[i, :len(chunk)] = chunk
                clens[i] = len(chunk)
            else:
                tokens[i, 0] = st.req.generated[-1]
                clens[i] = 1
                d = drafts.get(i) if self.spec is not None else None
                if d is not None and d.size:
                    # verify chunk: [t0, d1..dm] — the dist at index j
                    # scores the draft at j+1
                    tokens[i, 1:1 + d.size] = d
                    clens[i] = 1 + d.size
                    dlens[i] = d.size
                    self.drafted_tokens += int(d.size)
        nb = bucketize(sched.blocks_in_use(active, clens), self.block_buckets)
        bt = np.ascontiguousarray(sched.tables[:, :nb])
        temps = np.asarray([(sched.slots[i].req.temperature
                             if sched.slots[i] else 0.0) for i in range(B)],
                           np.float32)
        adapter_idx = (jnp.asarray(
            [(sched.slots[i].req.adapter_id if sched.slots[i] else 0)
             for i in range(B)], jnp.int32)
            if self.adapters is not None else None)
        self._rng, rng = jax.random.split(self._rng)
        self._signatures.add((C, nb))

        emit_np = n_emit_np = None
        if self.spec is None:
            toks_out, self.cache, dropped = self._step(
                self.params, self.adapters, self.cache,
                jnp.asarray(tokens), jnp.asarray(sched.lens.copy()),
                jnp.asarray(clens), jnp.asarray(bt), adapter_idx, rng,
                jnp.asarray(temps))
            toks_np = np.asarray(toks_out)
        else:
            self.spec_steps += 1
            tok_last, emit, n_emit, self.cache, dropped = self._spec_step(
                self.params, self.adapters, self.cache,
                jnp.asarray(tokens), jnp.asarray(sched.lens.copy()),
                jnp.asarray(clens), jnp.asarray(dlens),
                jnp.asarray(bt), adapter_idx, rng, jnp.asarray(temps))
            toks_np = np.asarray(tok_last)
            emit_np, n_emit_np = np.asarray(emit), np.asarray(n_emit)
        _track_drops(self, dropped)

        # ---- advance + sample + retire
        for i in active:
            st = sched.slots[i]
            req = st.req
            if phase[i] == "decode" and self.spec is not None:
                self._advance_spec(i, int(dlens[i]), emit_np[i],
                                   int(n_emit_np[i]))
                continue
            sched.lens[i] += int(clens[i])
            if phase[i] == "decode":
                self.decode_tokens += 1
                req.generated.append(int(toks_np[i]))
            else:
                self.prefill_tokens += int(clens[i])
                if self.prefix is not None:
                    self._register_progress(i)
                if sched.lens[i] < _stream_len(req):
                    continue                    # mid-prompt
                if not req.generated:           # fresh prefill done
                    req.generated.append(int(toks_np[i]))
                # else: resumed prefill done — next tick decodes generated[-1]
            tok = req.generated[-1]
            hit_eos = req.eos_id is not None and tok == req.eos_id
            # the length cut-off only applies after a decode write (mirrors
            # the dense engine, which always decodes at least once after
            # prefill — keeps paged==dense at prompt_len == max_len-1)
            len_cap = (phase[i] == "decode"
                       and int(sched.lens[i]) >= self.max_len - 1)
            if len(req.generated) >= req.max_new_tokens or hit_eos or len_cap:
                req.done = True
                req.finish_reason = "eos" if hit_eos else "length"
                self.finished[req.uid] = req
                if (self.prefix is not None
                        and len(req.prompt) % self.layout.page_size):
                    # donate the partial prompt-tail page to the index —
                    # future sharers fork it copy-on-write at divergence
                    self.prefix.register_tail(
                        req.adapter_id, req.prompt,
                        st.pages[len(req.prompt) // self.layout.page_size],
                        self._tick)
                sched.release(i)

    def run_until_done(self, max_ticks: int = 100_000) -> Dict[int, Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.sched.active():
                break
            self.step()
        return self.finished

    def drain(self, max_ticks: int = 100_000) -> Dict[int, Completion]:
        self.run_until_done(max_ticks)
        return {uid: completion_of(r) for uid, r in self.finished.items()}

    def release_prefix_cache(self) -> int:
        """Drop every prefix-index page ref (pages whose only holder was
        the index return to the free list). Returns pages freed."""
        return self.prefix.clear() if self.prefix is not None else 0

    def save_prefix_cache(self, path: Optional[str] = None) -> int:
        """Serialize the prefix index (trie + page contents) so a future
        engine warm-starts from it (``prefix_cache_path=``). Returns the
        number of pages written."""
        if self.prefix is None:
            raise ValueError("prefix cache is disabled on this engine")
        path = path or self.prefix_cache_path
        if path is None:
            raise ValueError("no path: pass save_prefix_cache(path) or "
                             "construct with prefix_cache_path=")
        return self.prefix.save(path, self.cache)

    # ------------------------------------------------------------------
    def _parallel_stats(self) -> ParallelStats:
        if self.mesh is None:
            return ParallelStats()

        def per_device(tree) -> int:
            return sum(
                int(np.prod(l.sharding.shard_shape(l.shape)))
                * l.dtype.itemsize for l in jax.tree.leaves(tree))

        return ParallelStats(
            tp=self.parallel.tp,
            devices=tuple(str(d) for d in self.mesh.devices.flat),
            mesh_axes=tuple(self.mesh.axis_names),
            param_bytes_per_device=per_device(self.params),
            kv_bytes_per_device=per_device(self.cache))

    def stats(self) -> EngineStats:
        occ = self.sched.occupancy()
        spec_stats = SpecStats(enabled=self.spec is not None)
        if self.spec is not None:
            drafter_sigs = (self.drafter.stats()
                            if hasattr(self.drafter, "stats") else None)
            spec_stats = SpecStats(
                enabled=True,
                k=self.spec.k, drafter=self.spec.drafter,
                steps=self.spec_steps,
                drafted_tokens=self.drafted_tokens,
                accepted_tokens=self.accepted_tokens,
                rolled_back_tokens=self.rolled_back_tokens,
                recurrent_rollbacks=self.sched.recurrent_rollbacks,
                accept_rate=(self.accepted_tokens
                             / max(self.drafted_tokens, 1)),
                draft_signatures=tuple(
                    tuple(s) for s in drafter_sigs["draft_signatures"])
                if drafter_sigs else (),
                draft_compiles=(drafter_sigs["draft_compiles"]
                                if drafter_sigs else None))
        prefix_stats = PrefixCacheStats(
            enabled=self.prefix is not None,
            hit_tokens=self.prefix_hit_tokens,
            hits=self.prefix_hits,
            loaded_pages=self.prefix_loaded_pages,
            **(self.prefix.stats() if self.prefix is not None else {}))
        return EngineStats(
            engine="paged",
            ticks=self._tick,
            decode_tokens=self.decode_tokens,
            prefill_tokens=self.prefill_tokens,
            compile=CompileStats(
                step_signatures=tuple(sorted(self._signatures)),
                compiled_steps=len(self._signatures),
                # _cache_size is jit-internal; fall back to our accounting
                jit_cache_size=int(getattr(
                    self._step, "_cache_size",
                    lambda: len(self._signatures))())),
            scheduler=SchedulerStats(**occ),
            prefix_cache=prefix_stats,
            spec=spec_stats,
            moe=MoEStats(enabled=self._has_moe,
                         dispatch=self.ec.moe_dispatch,
                         dropped_tokens=self.moe_dropped_tokens),
            parallel=self._parallel_stats())
