"""Batched serving engine: continuous batching + multi-adapter LoRA decode.

The paper's inference story (SS V.G): the frozen base lives on-chip
(crossbar-quantized); switching tasks means swapping only LoRA adapters —
"a fraction of the pre-trained model parameters". Here that becomes
multi-tenant serving: adapters are stacked along a leading dim and every
request carries an adapter id; one batched decode step serves a mixed batch
of tasks (S-LoRA-style), with per-slot KV caches in a fixed arena.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lora as lora_lib
from repro.models import kvcache, transformer as tfm
from repro.models.transformer import ExecConfig


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    adapter_id: int = 0
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching over a fixed decode arena."""

    def __init__(self, cfg: ModelConfig, params, adapters: Sequence = (), *,
                 max_batch: int = 8, max_len: int = 512,
                 exec_cfg: ExecConfig = ExecConfig(), seed: int = 0):
        self.cfg, self.params = cfg, params
        self.ec = exec_cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.adapters = (lora_lib.stack_adapters(list(adapters))
                         if adapters else None)
        self.cache = kvcache.init_cache(cfg, max_batch, max_len,
                                        kv_dtype=jnp.float32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("plen",))

    # ------------------------------------------------------------------
    def _adapter_idx(self):
        return jnp.asarray([r.adapter_id if r else 0 for r in self.slot_req],
                           jnp.int32)

    def _prefill_fn(self, params, adapters, cache, tokens, positions, mask,
                    slot, adapter_idx, plen):
        """Prefill one request into its slot via repeated decode steps is
        wasteful; instead run a full forward and scatter the produced cache
        rows into the arena at ``slot``."""
        logits, req_cache, _ = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters,
            positions=positions, mode="prefill",
            prefill_cache_len=self.max_len, exec_cfg=self.ec,
            adapter_idx=adapter_idx)

        def merge(arena, row):
            # every cache leaf is (n_sp, B, ...): scatter the request's row
            # (B=1) into the arena at its slot
            return jax.lax.dynamic_update_slice_in_dim(
                arena, row.astype(arena.dtype), slot, axis=1)

        merged = jax.tree.map(merge, cache, req_cache)
        return logits[:, -1, :], merged

    def _decode_fn(self, params, adapters, cache, tokens, positions,
                   adapter_idx, rng, temps):
        logits, new_cache, _ = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters, cache=cache,
            positions=positions, mode="decode", exec_cfg=self.ec,
            adapter_idx=adapter_idx)
        logits = logits[:, -1, :]
        greedy = jnp.argmax(logits, -1)
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(rng, logits.shape, minval=1e-9, maxval=1.0)))
        sampled = jnp.argmax(logits / jnp.maximum(temps[:, None], 1e-6)
                             + gumbel, -1)
        toks = jnp.where(temps > 0, sampled, greedy)
        return toks, new_cache

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                plen = len(req.prompt)
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                pos = jnp.arange(plen, dtype=jnp.int32)[None]
                adapter_idx = (jnp.asarray([req.adapter_id], jnp.int32)
                               if self.adapters is not None else None)
                last_logits, self.cache = self._prefill(
                    self.params, self.adapters, self.cache, toks, pos,
                    None, i, adapter_idx, plen)
                tok = int(jnp.argmax(last_logits[0]))
                req.generated.append(tok)
                self.slot_pos[i] = plen

    def step(self) -> None:
        """One engine tick: admit queued requests, run one batched decode
        step for every active slot, retire finished requests."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        last = [(self.slot_req[i].generated[-1]
                 if self.slot_req[i] is not None and self.slot_req[i].generated
                 else 0) for i in range(self.max_batch)]
        toks = jnp.asarray(last, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        temps = jnp.asarray([r.temperature if r else 0.0
                             for r in self.slot_req], jnp.float32)
        self._rng, rng = jax.random.split(self._rng)
        idx = self._adapter_idx() if self.adapters is not None else None
        toks_out, self.cache = self._decode(
            self.params, self.adapters, self.cache, toks, pos, idx, rng,
            temps)
        toks_np = np.asarray(toks_out)
        for i in active:
            req = self.slot_req[i]
            self.slot_pos[i] += 1
            tok = int(toks_np[i])
            req.generated.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                self.finished[req.uid] = req
                self.slot_req[i] = None
                self.slot_pos[i] = 0

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished
