"""Serving engines: continuous batching + multi-adapter LoRA decode.

The paper's inference story (SS V.G): the frozen base lives on-chip
(crossbar-quantized); switching tasks means swapping only LoRA adapters —
"a fraction of the pre-trained model parameters". Here that becomes
multi-tenant serving: adapters are stacked along a leading dim and every
request carries an adapter id; one batched step serves a mixed batch of
tasks (S-LoRA-style).

Two engines share the Request/submit/step/run_until_done API:

  * ``ServeEngine`` — the dense baseline: per-slot KV rows in a fixed
    ``max_batch x max_len`` arena, one whole-prompt prefill compile per
    distinct prompt length.
  * ``PagedServeEngine`` — the production engine: full-attention KV lives
    in a shared page pool addressed by per-request block tables
    (vLLM-style); prefill runs in fixed-width chunks drawn from a small
    set of padded buckets; prefill chunks and decode steps run through ONE
    fully-jitted mixed step whose compile count is O(#chunk buckets x
    #table-width buckets) instead of O(#prompt lengths). Admission and
    eviction are decided by page occupancy (``serve.scheduler``), and the
    cache is donated through ``jax.jit(..., donate_argnums=...)`` so decode
    updates the arena in place on accelerators.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lora as lora_lib
from repro.models import kvcache, transformer as tfm
from repro.models.kvcache import PagedLayout
from repro.models.transformer import ExecConfig
from repro.serve.scheduler import PageScheduler, bucketize, power_buckets


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    adapter_id: int = 0
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False


def _validate_request(req: Request, max_len: int) -> None:
    """Shared admission contract: both engines fail fast at submit."""
    if len(req.prompt) == 0:
        raise ValueError(f"request uid={req.uid}: empty prompt")
    if len(req.prompt) + 1 > max_len:
        raise ValueError(f"request uid={req.uid}: prompt of "
                         f"{len(req.prompt)} tokens exceeds "
                         f"max_len={max_len}")


def _sample(logits, temps, rng):
    """Greedy when temp == 0, seeded Gumbel-max otherwise. logits (B, V)."""
    greedy = jnp.argmax(logits, -1)
    gumbel = -jnp.log(-jnp.log(
        jax.random.uniform(rng, logits.shape, minval=1e-9, maxval=1.0)))
    sampled = jnp.argmax(logits / jnp.maximum(temps[:, None], 1e-6)
                         + gumbel, -1)
    return jnp.where(temps > 0, sampled, greedy)


# ---------------------------------------------------------------------------
# Dense baseline
# ---------------------------------------------------------------------------


class ServeEngine:
    """Slot-based continuous batching over a fixed dense decode arena."""

    def __init__(self, cfg: ModelConfig, params, adapters: Sequence = (), *,
                 max_batch: int = 8, max_len: int = 512,
                 exec_cfg: ExecConfig = ExecConfig(), seed: int = 0):
        self.cfg, self.params = cfg, params
        self.ec = exec_cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.adapters = (lora_lib.stack_adapters(list(adapters))
                         if adapters else None)
        self.cache = kvcache.init_cache(cfg, max_batch, max_len,
                                        kv_dtype=jnp.float32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("plen",))

    # ------------------------------------------------------------------
    def _adapter_idx(self):
        return jnp.asarray([r.adapter_id if r else 0 for r in self.slot_req],
                           jnp.int32)

    def _prefill_fn(self, params, adapters, cache, tokens, positions, mask,
                    slot, adapter_idx, plen):
        """Prefill one request into its slot via repeated decode steps is
        wasteful; instead run a full forward and scatter the produced cache
        rows into the arena at ``slot``."""
        logits, req_cache, _ = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters,
            positions=positions, mode="prefill",
            prefill_cache_len=self.max_len, exec_cfg=self.ec,
            adapter_idx=adapter_idx)

        def merge(arena, row):
            # every cache leaf is (n_sp, B, ...): scatter the request's row
            # (B=1) into the arena at its slot
            return jax.lax.dynamic_update_slice_in_dim(
                arena, row.astype(arena.dtype), slot, axis=1)

        merged = jax.tree.map(merge, cache, req_cache)
        return logits[:, -1, :], merged

    def _decode_fn(self, params, adapters, cache, tokens, positions,
                   adapter_idx, rng, temps):
        logits, new_cache, _ = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters, cache=cache,
            positions=positions, mode="decode", exec_cfg=self.ec,
            adapter_idx=adapter_idx)
        return _sample(logits[:, -1, :], temps, rng), new_cache

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        _validate_request(req, self.max_len)
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                plen = len(req.prompt)
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                pos = jnp.arange(plen, dtype=jnp.int32)[None]
                adapter_idx = (jnp.asarray([req.adapter_id], jnp.int32)
                               if self.adapters is not None else None)
                last_logits, self.cache = self._prefill(
                    self.params, self.adapters, self.cache, toks, pos,
                    None, i, adapter_idx, plen)
                self._rng, rng = jax.random.split(self._rng)
                temps1 = jnp.asarray([req.temperature], jnp.float32)
                tok = int(np.asarray(_sample(last_logits, temps1, rng))[0])
                req.generated.append(tok)
                self.slot_pos[i] = plen

    def step(self) -> None:
        """One engine tick: admit queued requests, run one batched decode
        step for every active slot, retire finished requests."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        last = [(self.slot_req[i].generated[-1]
                 if self.slot_req[i] is not None and self.slot_req[i].generated
                 else 0) for i in range(self.max_batch)]
        toks = jnp.asarray(last, jnp.int32)[:, None]
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        temps = jnp.asarray([r.temperature if r else 0.0
                             for r in self.slot_req], jnp.float32)
        self._rng, rng = jax.random.split(self._rng)
        idx = self._adapter_idx() if self.adapters is not None else None
        toks_out, self.cache = self._decode(
            self.params, self.adapters, self.cache, toks, pos, idx, rng,
            temps)
        toks_np = np.asarray(toks_out)
        for i in active:
            req = self.slot_req[i]
            self.slot_pos[i] += 1
            tok = int(toks_np[i])
            req.generated.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (len(req.generated) >= req.max_new_tokens or hit_eos
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                self.finished[req.uid] = req
                self.slot_req[i] = None
                self.slot_pos[i] = 0

    def run_until_done(self, max_ticks: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished


# ---------------------------------------------------------------------------
# Paged engine
# ---------------------------------------------------------------------------


def _stream(req: Request) -> np.ndarray:
    """Tokens that belong in the cache: the prompt plus every generated
    token except the newest (which is the next decode input)."""
    if len(req.generated) <= 1:
        return np.asarray(req.prompt, np.int32)
    return np.concatenate([np.asarray(req.prompt, np.int32),
                           np.asarray(req.generated[:-1], np.int32)])


def _stream_len(req: Request) -> int:
    """len(_stream(req)) without materializing the concatenation."""
    return len(req.prompt) + max(0, len(req.generated) - 1)


class PagedServeEngine:
    """Continuous batching over a paged KV arena with chunked prefill.

    Every tick runs ONE jitted mixed step over all ``max_slots`` rows:
    rows mid-prompt consume a chunk of up to ``prefill_chunk`` tokens,
    decoding rows consume their last sampled token, idle rows are masked
    out via ``chunk_lens == 0``. The step specializes only on the
    (chunk-bucket, table-width-bucket) pair, so total compiles are
    O(log max_len), independent of how many distinct prompt lengths the
    traffic contains.
    """

    def __init__(self, cfg: ModelConfig, params, adapters: Sequence = (), *,
                 max_slots: int = 16, max_len: int = 512, page_size: int = 16,
                 num_pages: Optional[int] = None, prefill_chunk: int = 32,
                 exec_cfg: ExecConfig = ExecConfig(), seed: int = 0):
        self.cfg, self.params = cfg, params
        self.ec = exec_cfg
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        if num_pages is None:
            # default: half of the dense arena's footprint — mixed traffic
            # rarely keeps every slot at max_len
            num_pages = max(max_slots * (-(-max_len // page_size)) // 2,
                            -(-max_len // page_size) + 1)
        self.layout = PagedLayout(page_size=page_size, num_pages=num_pages,
                                  max_slots=max_slots)
        self.adapters = (lora_lib.stack_adapters(list(adapters))
                         if adapters else None)
        self.cache = kvcache.init_paged_cache(cfg, self.layout, max_len,
                                              kv_dtype=jnp.float32)
        self.sched = PageScheduler(self.layout, max_len)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._rng = jax.random.PRNGKey(seed)
        self.chunk_buckets = power_buckets(prefill_chunk)
        self.block_buckets = power_buckets(self.sched.max_blocks)
        self._step = jax.jit(self._step_fn, donate_argnums=(2,))
        self._signatures: Set[Tuple[int, int]] = set()
        self._tick = 0
        self.decode_tokens = 0

    # ------------------------------------------------------------------
    def _step_fn(self, params, adapters, cache, tokens, lens, clens,
                 block_table, adapter_idx, rng, temps):
        B, C = tokens.shape
        positions = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        paged = {"block_table": block_table, "lens": lens,
                 "chunk_lens": clens, "page_size": self.layout.page_size}
        logits, new_cache, _ = tfm.forward(
            self.cfg, params, {"tokens": tokens}, lora=adapters, cache=cache,
            positions=positions, mode="decode", exec_cfg=self.ec,
            adapter_idx=adapter_idx, paged=paged, chunk_lens=clens)
        last = jnp.clip(clens - 1, 0, C - 1)[:, None, None]
        lg = jnp.take_along_axis(
            logits, jnp.broadcast_to(last, (B, 1, logits.shape[-1])),
            axis=1)[:, 0]
        return _sample(lg, temps, rng), new_cache

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        _validate_request(req, self.max_len)
        if (self.layout.blocks_for(len(req.prompt) + 1)
                > self.layout.num_pages):
            raise ValueError(
                f"request uid={req.uid}: prompt of {len(req.prompt)} tokens "
                f"needs more pages than the pool holds "
                f"({self.layout.num_pages} pages of {self.layout.page_size})")
        self.queue.append(req)

    def _admit(self) -> None:
        fresh = []
        while self.queue:
            req = self.queue[0]
            slot = self.sched.admit(req, _stream_len(req), self._tick)
            if slot is None:
                if not self.sched.active():
                    raise RuntimeError(
                        f"request uid={req.uid} needs more pages than the "
                        f"pool holds ({self.layout.num_pages} pages of "
                        f"{self.layout.page_size})")
                break
            self.queue.pop(0)
            fresh.append(slot)
        if fresh:
            # recycled slots carry stale ring/recurrent rows — zero them
            self.cache = kvcache.reset_slots(self.cache, fresh)

    def step(self) -> None:
        """One tick: admit, build a mixed ragged chunk, run the jitted
        step, advance lengths, sample/retire."""
        self._tick += 1
        self._admit()
        sched = self.sched
        active = sched.active()
        if not active:
            return
        B = self.layout.max_slots

        # ---- per-slot chunk widths
        want = np.zeros(B, np.int32)
        phase: Dict[int, str] = {}
        for i in active:
            st = sched.slots[i]
            remaining = _stream_len(st.req) - int(sched.lens[i])
            if remaining > 0:
                want[i] = min(remaining, self.prefill_chunk)
                phase[i] = "prefill"
            else:
                want[i] = 1
                phase[i] = "decode"

        # ---- page capacity (oldest slots are protected; pool pressure
        # preempts the youngest, which requeues for recompute)
        protected: List[int] = []
        for i in sorted(active,
                        key=lambda j: sched.slots[j].admitted_tick):
            if sched.slots[i] is None:      # preempted as someone's victim
                continue
            sched.ensure(i, int(sched.lens[i]) + int(want[i]),
                         protect=protected + [i])
            if sched.slots[i] is not None:
                protected.append(i)
        for req in reversed(sched.drain_evicted()):
            if (self.layout.blocks_for(_stream_len(req) + 1)
                    > self.layout.num_pages):
                # the stream has outgrown the entire pool — retire at
                # capacity, mirroring the dense engine's max_len cut-off
                req.done = True
                self.finished[req.uid] = req
            else:
                self.queue.insert(0, req)
        active = sched.active()
        if not active:
            return

        # ---- assemble the mixed batch
        C = bucketize(int(max(want[i] for i in active)), self.chunk_buckets)
        tokens = np.zeros((B, C), np.int32)
        clens = np.zeros(B, np.int32)
        for i in active:
            st = sched.slots[i]
            if phase[i] == "prefill":
                stream = _stream(st.req)
                L = int(sched.lens[i])
                chunk = stream[L:L + int(want[i])]
                tokens[i, :len(chunk)] = chunk
                clens[i] = len(chunk)
            else:
                tokens[i, 0] = st.req.generated[-1]
                clens[i] = 1
        nb = bucketize(sched.blocks_in_use(active, clens), self.block_buckets)
        bt = np.ascontiguousarray(sched.tables[:, :nb])
        temps = np.asarray([(sched.slots[i].req.temperature
                             if sched.slots[i] else 0.0) for i in range(B)],
                           np.float32)
        adapter_idx = (jnp.asarray(
            [(sched.slots[i].req.adapter_id if sched.slots[i] else 0)
             for i in range(B)], jnp.int32)
            if self.adapters is not None else None)
        self._rng, rng = jax.random.split(self._rng)
        self._signatures.add((C, nb))

        toks_out, self.cache = self._step(
            self.params, self.adapters, self.cache,
            jnp.asarray(tokens), jnp.asarray(sched.lens.copy()),
            jnp.asarray(clens), jnp.asarray(bt), adapter_idx, rng,
            jnp.asarray(temps))
        toks_np = np.asarray(toks_out)

        # ---- advance + sample + retire
        for i in active:
            st = sched.slots[i]
            req = st.req
            sched.lens[i] += int(clens[i])
            if phase[i] == "decode":
                self.decode_tokens += 1
                req.generated.append(int(toks_np[i]))
            else:
                if sched.lens[i] < _stream_len(req):
                    continue                    # mid-prompt
                if not req.generated:           # fresh prefill done
                    req.generated.append(int(toks_np[i]))
                # else: resumed prefill done — next tick decodes generated[-1]
            tok = req.generated[-1]
            hit_eos = req.eos_id is not None and tok == req.eos_id
            # the length cut-off only applies after a decode write (mirrors
            # the dense engine, which always decodes at least once after
            # prefill — keeps paged==dense at prompt_len == max_len-1)
            len_cap = (phase[i] == "decode"
                       and int(sched.lens[i]) >= self.max_len - 1)
            if len(req.generated) >= req.max_new_tokens or hit_eos or len_cap:
                req.done = True
                self.finished[req.uid] = req
                sched.release(i)

    def run_until_done(self, max_ticks: int = 100_000) -> Dict[int, Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.sched.active():
                break
            self.step()
        return self.finished

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        occ = self.sched.occupancy()
        return {
            "ticks": self._tick,
            "decode_tokens": self.decode_tokens,
            "step_signatures": sorted(self._signatures),
            "compiled_steps": len(self._signatures),
            # _cache_size is jit-internal; fall back to our own accounting
            "jit_cache_size": int(getattr(self._step, "_cache_size",
                                          lambda: len(self._signatures))()),
            **occ,
        }
