"""Radix prefix index over submitted prompt tokens -> resident KV pages.

The paged serving engine never recomputes KV for a prompt prefix two
requests share: the index maps token streams onto pages that already hold
their K/V. Structure is a per-adapter radix trie whose edges are FULL pages
of tokens (``page_size`` each) — a node's page holds exactly the K/V those
tokens produce, which is deterministic given (tokens, positions, adapter),
so any request whose prompt walks the same edge chain may map the same
pages into its block table and skip prefill up to the first unshared token.

Partial last pages are indexed too (``tails``): a finished request donates
its prompt-tail page, and a later request matching ``m`` of its tokens
shares the page mid-way — the sharer's first write into it then triggers a
copy-on-write fork (the engine forks every shared page before writing, so
index-held pages are immutable by construction).

Refcounting: the index holds exactly ONE allocator ref per node/tail page.
Active slots stack their own refs on top, so a page whose refcount is 1 is
held only by the index — those are the evictable ones. Eviction is
leaf-only (an interior node's children would become unreachable) and
youngest-first, mirroring the scheduler's youngest-first preemption: the
oldest, hottest prefixes survive pool pressure longest.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.models import kvcache
from repro.models.kvcache import PageAllocator

Key = Tuple[int, ...]


@dataclass
class _Tail:
    """A partial last page: ``tokens`` (fewer than page_size of them) whose
    K/V occupy the first ``len(tokens)`` rows of ``page``."""
    tokens: Key
    page: int
    tick: int


@dataclass
class _Node:
    """One full page of tokens; ``page`` holds their K/V."""
    key: Key
    page: int
    tick: int
    children: Dict[Key, "_Node"] = field(default_factory=dict)
    tails: List[_Tail] = field(default_factory=list)


@dataclass
class _Root:
    """Per-adapter synthetic root (no page of its own)."""
    children: Dict[Key, _Node] = field(default_factory=dict)
    tails: List[_Tail] = field(default_factory=list)


class PrefixIndex:
    """Host-side prefix cache over the shared page pool."""

    def __init__(self, alloc: PageAllocator, page_size: int,
                 max_tails: int = 4):
        self.alloc = alloc
        self.page_size = page_size
        self.max_tails = max_tails
        self._roots: Dict[int, _Root] = {}
        self.nodes = 0
        self.tail_entries = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def _root(self, adapter_id: int) -> _Root:
        return self._roots.setdefault(adapter_id, _Root())

    @staticmethod
    def _common(a: Key, b: Sequence[int]) -> int:
        n = 0
        for x, y in zip(a, b):
            if x != int(y):
                break
            n += 1
        return n

    @property
    def pages_held(self) -> int:
        return self.nodes + self.tail_entries

    # ------------------------------------------------------------------
    def lookup(self, adapter_id: int,
               tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest indexed prefix of ``tokens``: (matched_tokens, pages).

        Pure — takes no refs; the scheduler increfs the pages if (and only
        if) the request actually admits with them."""
        root = self._roots.get(adapter_id)
        if root is None:
            return 0, []
        P = self.page_size
        node: object = root
        pages: List[int] = []
        matched = 0
        while len(tokens) - matched >= P:
            key = tuple(int(t) for t in tokens[matched:matched + P])
            child = node.children.get(key)
            if child is None:
                break
            pages.append(child.page)
            matched += P
            node = child
        best_m, best_page = 0, -1
        for t in node.tails:
            m = self._common(t.tokens, tokens[matched:])
            if m > best_m:
                best_m, best_page = m, t.page
        if best_m:
            pages.append(best_page)
            matched += best_m
        return matched, pages

    def matchable_full_pages(self, adapter_id: int, a: Sequence[int],
                             b: Sequence[int]) -> int:
        """Full pages ``a`` could share with ``b``'s stream beyond what the
        index already resolves — used to defer admission while the request
        that will donate those pages is still mid-prefill."""
        common = self._common(tuple(int(t) for t in a), b) // self.page_size
        already = self.lookup(adapter_id, a[:common * self.page_size])[0]
        return common - already // self.page_size

    # ------------------------------------------------------------------
    def register(self, adapter_id: int, tokens: Sequence[int],
                 pages: Sequence[int], tick: int) -> int:
        """Insert the FULL pages of ``tokens`` (len // page_size of them,
        covered by ``pages[i]``). Existing nodes are kept (first writer
        wins — key equality implies identical K/V content), so repeated
        progressive registration during chunked prefill is cheap. Returns
        the number of newly indexed pages (each takes one allocator ref)."""
        node: object = self._root(adapter_id)
        P = self.page_size
        added = 0
        for i in range(len(tokens) // P):
            key = tuple(int(t) for t in tokens[i * P:(i + 1) * P])
            child = node.children.get(key)
            if child is None:
                child = _Node(key=key, page=pages[i], tick=tick)
                node.children[key] = child
                self.alloc.incref(pages[i])
                self.nodes += 1
                added += 1
            else:
                child.tick = tick
            node = child
        return added

    def register_tail(self, adapter_id: int, tokens: Sequence[int],
                      page: int, tick: int) -> bool:
        """Donate a partial prompt-tail page (the ``len(tokens) %
        page_size`` trailing tokens live in ``page``). Requires the
        full-page chain to still be indexed; skipped when an existing tail
        already covers these tokens."""
        P = self.page_size
        n_full = len(tokens) // P
        rem = tuple(int(t) for t in tokens[n_full * P:])
        if not rem:
            return False
        node: object = self._root(adapter_id)
        for i in range(n_full):
            key = tuple(int(t) for t in tokens[i * P:(i + 1) * P])
            node = node.children.get(key)
            if node is None:
                return False
        for t in node.tails:
            if t.tokens[:len(rem)] == rem:
                return False
        if len(node.tails) >= self.max_tails:
            return False
        node.tails.append(_Tail(tokens=rem, page=page, tick=tick))
        self.alloc.incref(page)
        self.tail_entries += 1
        return True

    # ------------------------------------------------------------------
    def _evictable(self):
        """(tick, kind, container, item) for every leaf whose page is held
        ONLY by the index (allocator refcount == 1)."""
        out = []

        def walk(node):
            for t in node.tails:
                if self.alloc.refcount(t.page) == 1:
                    out.append((t.tick, "tail", node, t))
            for child in node.children.values():
                if (not child.children and not child.tails
                        and self.alloc.refcount(child.page) == 1):
                    out.append((child.tick, "node", node, child))
                walk(child)

        for root in self._roots.values():
            walk(root)
        return out

    def evict(self, need: int) -> int:
        """Free up to ``need`` pages, youngest (most recently registered)
        leaves first; only refcount-1 pages — anything an active slot still
        maps is untouchable. Returns pages actually freed."""
        freed = 0
        while freed < max(need, 1):
            cands = self._evictable()
            if not cands:
                break
            # youngest-first, one sweep per round (evicting a leaf can
            # expose its parent as the next candidate)
            cands.sort(key=lambda c: -c[0])
            for _, kind, container, item in cands:
                if freed >= max(need, 1):
                    break
                if kind == "tail":
                    container.tails.remove(item)
                    self.tail_entries -= 1
                else:
                    del container.children[item.key]
                    self.nodes -= 1
                freed += 1 if self.alloc.decref(item.page) else 0
                self.evictions += 1
        return freed

    def clear(self) -> int:
        """Drop every index ref (e.g. at engine shutdown). Returns pages
        actually freed."""
        freed = 0

        def walk(node):
            nonlocal freed
            for t in node.tails:
                freed += 1 if self.alloc.decref(t.page) else 0
            for child in node.children.values():
                freed += 1 if self.alloc.decref(child.page) else 0
                walk(child)

        for root in self._roots.values():
            walk(root)
        self._roots = {}
        self.nodes = 0
        self.tail_entries = 0
        return freed

    def stats(self) -> Dict[str, int]:
        return {"index_nodes": self.nodes, "index_tails": self.tail_entries,
                "index_pages": self.pages_held,
                "index_evictions": self.evictions}

    # ------------------------------------------------------------------
    # Persistence: serialize trie + the page contents it references, so a
    # fresh engine starts with a warm prefix cache (make_engine(...,
    # prefix_cache_path=...)). Page IDS are not stable across restarts —
    # the loader re-allocates pages from the new pool and remaps.
    # ------------------------------------------------------------------

    def save(self, path: str, cache) -> int:
        """Write the whole index (trie structure + K/V page contents) to
        ``path`` (npz). Returns the number of pages serialized. The page
        snapshot is taken via ``kvcache.gather_pages`` — valid because
        index-held pages are immutable by construction (writers always
        CoW-fork first)."""
        P = self.page_size
        nodes: List[Tuple[int, int, Key, int, int]] = []   # aid,parent,key,page,tick
        tails: List[Tuple[int, int, Key, int, int]] = []

        def walk(node, parent: int, aid: int) -> None:
            for t in node.tails:
                tails.append((aid, parent, t.tokens, t.page, t.tick))
            for child in node.children.values():
                idx = len(nodes)
                nodes.append((aid, parent, child.key, child.page, child.tick))
                walk(child, idx, aid)

        for aid, root in self._roots.items():
            walk(root, -1, aid)

        n, m = len(nodes), len(tails)
        node_tokens = np.zeros((n, P), np.int64)
        node_meta = np.zeros((n, 3), np.int64)             # adapter,parent,tick
        tail_tokens = np.zeros((m, P), np.int64)
        tail_meta = np.zeros((m, 4), np.int64)             # adapter,parent,len,tick
        pages: List[int] = []
        for i, (aid, parent, key, page, tick) in enumerate(nodes):
            node_tokens[i] = key
            node_meta[i] = (aid, parent, tick)
            pages.append(page)
        for i, (aid, parent, key, page, tick) in enumerate(tails):
            tail_tokens[i, :len(key)] = key
            tail_meta[i] = (aid, parent, len(key), tick)
            pages.append(page)
        data = kvcache.gather_pages(cache, pages)
        arrs = {f"pool_{li}_{name}": arr
                for li, entry in enumerate(data)
                for name, arr in entry.items()}
        with open(path, "wb") as f:
            np.savez(f, page_size=np.int64(P), n_positions=np.int64(len(data)),
                     node_tokens=node_tokens, node_meta=node_meta,
                     tail_tokens=tail_tokens, tail_meta=tail_meta, **arrs)
        return n + m

    def load(self, path: str, cache):
        """Rebuild a saved index into THIS engine's (empty or live) pool.

        Allocates fresh pages (one index ref each, matching the invariant
        that the index holds exactly one allocator ref per page), scatters
        the saved K/V contents into them, and reconstructs the trie with
        remapped page ids. Entries that no longer fit (pool smaller than
        the snapshot, orphaned children) are skipped — loading is
        best-effort, never an error. Geometry (page_size, pool leaf
        shapes) must match or ``ValueError`` is raised.

        Returns ``(cache, pages_loaded)`` — the cache tree is rebuilt
        functionally, so callers must reassign it."""
        z = np.load(path)
        if int(z["page_size"]) != self.page_size:
            raise ValueError(
                f"prefix cache at {path!r} was saved with page_size="
                f"{int(z['page_size'])}, engine uses {self.page_size}")
        n_pos = int(z["n_positions"])
        saved = [{name: z[f"pool_{li}_{name}"]
                  for name in ("kp", "vp") if f"pool_{li}_{name}" in z}
                 for li in range(n_pos)]
        live = [{name: leaf for name, leaf in entry.items()
                 if name in ("kp", "vp")} for entry in cache["layers"]]
        if len(saved) != len(live) or any(
                set(s) != set(l) for s, l in zip(saved, live)):
            raise ValueError(f"prefix cache at {path!r} does not match this "
                             f"model's paged layer structure")
        for s, l in zip(saved, live):
            for name in s:
                a, b = s[name].shape, l[name].shape
                if (a[0],) + a[2:] != (b[0],) + b[2:]:
                    raise ValueError(
                        f"prefix cache at {path!r}: pool leaf {name} shape "
                        f"{a} incompatible with engine pool {b}")

        node_tokens, node_meta = z["node_tokens"], z["node_meta"]
        tail_tokens, tail_meta = z["tail_tokens"], z["tail_meta"]
        n = len(node_meta)
        # records are DFS order, so a node's parent always precedes it
        new_nodes: List[Optional[_Node]] = [None] * n
        rows: List[int] = []            # row in the saved page snapshot
        new_pages: List[int] = []
        for i in range(n):
            aid, parent, tick = (int(v) for v in node_meta[i])
            holder = self._root(aid) if parent < 0 else new_nodes[parent]
            if holder is None:          # parent didn't fit -> orphan
                continue
            key = tuple(int(t) for t in node_tokens[i])
            if key in holder.children:  # already indexed by live traffic
                new_nodes[i] = holder.children[key]
                continue
            got = self.alloc.alloc(1)
            if got is None:
                continue
            node = _Node(key=key, page=got[0], tick=tick)
            holder.children[key] = node
            new_nodes[i] = node
            self.nodes += 1
            rows.append(i)
            new_pages.append(got[0])
        for i in range(len(tail_meta)):
            aid, parent, tlen, tick = (int(v) for v in tail_meta[i])
            holder = self._root(aid) if parent < 0 else new_nodes[parent]
            if holder is None or len(holder.tails) >= self.max_tails:
                continue
            toks = tuple(int(t) for t in tail_tokens[i, :tlen])
            if any(t.tokens[:tlen] == toks for t in holder.tails):
                continue
            got = self.alloc.alloc(1)
            if got is None:
                continue
            holder.tails.append(_Tail(tokens=toks, page=got[0], tick=tick))
            self.tail_entries += 1
            rows.append(n + i)
            new_pages.append(got[0])
        if new_pages:
            subset = [{name: arr[:, rows] for name, arr in entry.items()}
                      for entry in saved]
            cache = kvcache.scatter_pages(cache, new_pages, subset)
        return cache, len(new_pages)
