"""Serving: the public surface is ``serve.api`` — Request/Completion, the
Engine protocol, and ``make_engine`` (the single construction point for the
paged production engine and the dense oracle)."""
from repro.serve.api import (Completion, Engine, Request, completion_of,
                             make_engine)

__all__ = ["Completion", "Engine", "Request", "completion_of", "make_engine"]
