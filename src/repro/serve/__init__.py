"""Serving: the public surface is ``serve.api`` — Request/Completion, the
Engine protocol, ``make_engine`` (the single construction point for the
paged production engine and the dense oracle), the ``ParallelConfig``
tensor-parallelism knob, and the typed ``EngineStats`` family — plus
``serve.spec`` for speculative decoding (``SpecConfig``, the ``Drafter``
protocol, and the built-in n-gram / quantized self-draft drafters)."""
from repro.serve.api import (Completion, CompileStats, Engine, EngineStats,
                             ParallelConfig, ParallelStats, PrefixCacheStats,
                             Request, SchedulerStats, SpecStats,
                             completion_of, make_engine)
from repro.serve.spec import (Drafter, NGramDrafter, QuantSelfDrafter,
                              SpecConfig, make_drafter)

__all__ = ["Completion", "CompileStats", "Engine", "EngineStats",
           "ParallelConfig", "ParallelStats", "PrefixCacheStats", "Request",
           "SchedulerStats", "SpecStats", "completion_of", "make_engine",
           "Drafter", "NGramDrafter", "QuantSelfDrafter", "SpecConfig",
           "make_drafter"]
