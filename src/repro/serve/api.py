"""The unified serving surface: one Request/Completion pair, one Engine
protocol, one factory, typed stats, and the parallelism knob.

Every launch path constructs engines through ``make_engine(cfg, params,
..., mode=...)``; the paged engine owns production serving and the dense
engine survives only as the equivalence oracle / benchmark baseline.

    eng = make_engine(cfg, params, adapters, mode="paged", max_slots=16,
                      parallel=ParallelConfig(tp=2))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=32))
    completions = eng.drain()          # {uid: Completion}
    st = eng.stats()                   # EngineStats (typed, frozen)
    print(st.scheduler.used_pages, st.parallel.tp)

Engines implement the ``Engine`` protocol: ``submit`` enqueues (failing
fast on infeasible requests), ``step`` runs one scheduler tick, ``drain``
runs ticks until the queue and slots are empty and returns immutable
``Completion`` records, ``stats`` returns an ``EngineStats`` — nested
frozen dataclasses for the compile/scheduler/prefix-cache/spec/moe/
parallel sections, with ``as_dict()`` as the flat-JSON escape hatch.
(The one-release dict-style access shim on ``EngineStats`` has been
removed — read the typed fields or call ``as_dict()``.)

Both engines force dropless MoE dispatch (``stats().moe`` reports the
mode and a ``dropped_tokens`` counter that serving asserts stays zero),
so greedy tokens are invariant to prefill chunking by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple,\
    runtime_checkable

import numpy as np


@dataclass
class Request:
    """One generation request. ``generated``/``done``/``finish_reason`` are
    filled by the engine as it serves the request."""
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    adapter_id: int = 0
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False
    finish_reason: str = ""             # "length" | "eos" | "capacity"


@dataclass(frozen=True)
class Completion:
    """Immutable result of one finished request."""
    uid: int
    prompt: Tuple[int, ...]
    tokens: Tuple[int, ...]             # generated tokens
    adapter_id: int
    finish_reason: str

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


def completion_of(req: Request) -> Completion:
    return Completion(uid=req.uid,
                      prompt=tuple(int(t) for t in req.prompt),
                      tokens=tuple(req.generated),
                      adapter_id=req.adapter_id,
                      finish_reason=req.finish_reason or "length")


# ---------------------------------------------------------------------------
# Parallelism knob
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    """How one engine spreads across local devices.

    ``tp`` is tensor-model parallelism: attention heads / head_dim, MoE
    expert slots, and FFN hidden dims split across a ``(1, tp)`` device
    mesh; the paged KV pool shards its head_dim axis (the ``paged_pool``
    rule in ``dist/sharding.py``). Everything host-side — block tables,
    scheduler state, CoW fork bookkeeping, rollback cursors, drafters —
    stays replicated, so prefix sharing and spec decoding compose
    unchanged. ``tp=1`` (the default) is byte-identical to the
    single-device engine."""
    tp: int = 1

    def __post_init__(self):
        if self.tp < 1:
            raise ValueError(f"ParallelConfig.tp must be >= 1, got {self.tp}")


# ---------------------------------------------------------------------------
# Typed stats
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CompileStats:
    """Jit-signature accounting. Paged engines fill the step_* fields
    (one signature per (chunk-bucket, table-width-bucket) pair); the dense
    oracle fills the prefill_* fields (one per prompt-length bucket)."""
    step_signatures: Tuple[Tuple[int, int], ...] = ()
    compiled_steps: int = 0
    jit_cache_size: int = 0
    prefill_signatures: Tuple[int, ...] = ()
    prefill_compiles: int = 0


@dataclass(frozen=True)
class SchedulerStats:
    """Page-pool occupancy + preemption/rollback/CoW counters (host-side
    state — replicated, not sharded, under tensor parallelism)."""
    used_pages: int = 0
    free_pages: int = 0
    shared_pages: int = 0
    peak_pages: int = 0
    preemptions: int = 0
    reclaimed_pages: int = 0
    rolled_back_pages: int = 0
    recurrent_rollbacks: int = 0        # full rewinds paired with a per-slot
    #                                     recurrent-state restore (spec on
    #                                     ring/Mamba/RWKV archs)
    cow_forks: int = 0


@dataclass(frozen=True)
class PrefixCacheStats:
    enabled: bool = False
    hit_tokens: int = 0
    hits: int = 0
    index_nodes: int = 0
    index_tails: int = 0
    index_pages: int = 0
    index_evictions: int = 0
    loaded_pages: int = 0              # pages restored via prefix_cache_path


@dataclass(frozen=True)
class SpecStats:
    """``recurrent_rollbacks`` counts verify chunks whose rejection was
    settled by restoring per-slot recurrent state (``SlotStateArena``)
    and replaying the accepted prefix — nonzero only on architectures
    with ring/Mamba/RWKV layers. ``disabled_reason`` survives for
    engines that cannot run spec at all (none today: the paged engine
    enables spec on every architecture)."""
    enabled: bool = False
    disabled_reason: Optional[str] = None
    k: int = 0
    drafter: str = ""
    steps: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    rolled_back_tokens: int = 0
    recurrent_rollbacks: int = 0
    accept_rate: float = 0.0
    # only drafters with their own jit cache (QuantSelfDrafter) report these
    draft_signatures: Tuple[Tuple[int, int], ...] = ()
    draft_compiles: Optional[int] = None


@dataclass(frozen=True)
class MoEStats:
    """MoE routing accounting. ``dispatch`` is the mode the engine forces
    ("dropless" for all serving rows — prefill chunks, decode rows,
    spec-verify tails; "capacity" only when explicitly requested for
    baseline comparison). ``dropped_tokens`` counts (token, expert)
    assignments dropped by capacity limits — identically 0 under
    dropless, and the engines raise if it ever isn't."""
    enabled: bool = False               # does the model have MoE layers?
    dispatch: str = "dropless"
    dropped_tokens: int = 0


@dataclass(frozen=True)
class ParallelStats:
    """Per-device placement under tensor parallelism. ``tp=1`` means the
    single-device engine (empty device list, zero per-device bytes)."""
    tp: int = 1
    devices: Tuple[str, ...] = ()
    mesh_axes: Tuple[str, ...] = ()
    param_bytes_per_device: int = 0
    kv_bytes_per_device: int = 0


@dataclass(frozen=True)
class EngineStats:
    """Typed engine counters (``Engine.stats()``).

    The nested sections are frozen dataclasses; ``scheduler``/
    ``prefix_cache``/``spec`` are ``None`` on the dense oracle (it has no
    page pool). ``as_dict()`` flattens to the exact legacy key set for the
    bench/CI JSON path. (Dict-style access — ``stats[key]`` / ``key in
    stats`` / ``stats.get`` — completed its one-release deprecation
    window and has been removed.)"""
    engine: str
    ticks: int
    decode_tokens: int
    prefill_tokens: int
    compile: CompileStats = CompileStats()
    scheduler: Optional[SchedulerStats] = None
    prefix_cache: Optional[PrefixCacheStats] = None
    spec: Optional[SpecStats] = None
    moe: MoEStats = MoEStats()
    parallel: ParallelStats = ParallelStats()
    kv_bytes: Optional[int] = None      # dense oracle only

    # ---- flat escape hatch (legacy key set) --------------------------
    def as_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {
            "engine": self.engine,
            "ticks": self.ticks,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "moe_dispatch": self.moe.dispatch,
            "moe_dropped_tokens": self.moe.dropped_tokens,
        }
        if self.scheduler is None:                      # dense oracle
            d.update({
                "prefill_signatures": list(self.compile.prefill_signatures),
                "prefill_compiles": self.compile.prefill_compiles,
                "kv_bytes": self.kv_bytes,
            })
            return d
        pc = self.prefix_cache or PrefixCacheStats()
        sp = self.spec or SpecStats()
        s = self.scheduler
        d.update({
            "prefix_hit_tokens": pc.hit_tokens,
            "prefix_hits": pc.hits,
            "prefix_cache_enabled": pc.enabled,
            "step_signatures": [tuple(sig) for sig
                                in self.compile.step_signatures],
            "compiled_steps": self.compile.compiled_steps,
            "jit_cache_size": self.compile.jit_cache_size,
            "live_pages": s.used_pages,
            "used_pages": s.used_pages,
            "free_pages": s.free_pages,
            "shared_pages": s.shared_pages,
            "peak_pages": s.peak_pages,
            "preemptions": s.preemptions,
            "reclaimed_pages": s.reclaimed_pages,
            "rolled_back_pages": s.rolled_back_pages,
            "recurrent_rollbacks": s.recurrent_rollbacks,
            "cow_forks": s.cow_forks,
            "spec_enabled": sp.enabled,
        })
        if sp.disabled_reason is not None:
            d["spec_disabled_reason"] = sp.disabled_reason
        if sp.enabled:
            d.update({
                "spec_k": sp.k,
                "spec_drafter": sp.drafter,
                "spec_steps": sp.steps,
                "drafted_tokens": sp.drafted_tokens,
                "accepted_tokens": sp.accepted_tokens,
                "rolled_back_tokens": sp.rolled_back_tokens,
                "spec_recurrent_rollbacks": sp.recurrent_rollbacks,
                "spec_accept_rate": sp.accept_rate,
            })
            if sp.draft_compiles is not None:
                d["draft_signatures"] = [tuple(sig) for sig
                                         in sp.draft_signatures]
                d["draft_compiles"] = sp.draft_compiles
        if pc.enabled:
            d.update({
                "index_nodes": pc.index_nodes,
                "index_tails": pc.index_tails,
                "index_pages": pc.index_pages,
                "index_evictions": pc.index_evictions,
            })
        if self.parallel.tp > 1:
            d.update({
                "tp": self.parallel.tp,
                "tp_devices": list(self.parallel.devices),
                "param_bytes_per_device":
                    self.parallel.param_bytes_per_device,
                "kv_bytes_per_device": self.parallel.kv_bytes_per_device,
            })
        return d


# ---------------------------------------------------------------------------
# Engine protocol + factory
# ---------------------------------------------------------------------------


@runtime_checkable
class Engine(Protocol):
    """What every serving engine exposes — nothing else is public API."""

    def submit(self, req: Request) -> None: ...
    def step(self) -> None: ...
    def drain(self, max_ticks: int = 100_000) -> Dict[int, Completion]: ...
    def stats(self) -> EngineStats: ...


def make_engine(cfg, params, adapters: Sequence = (), *,
                mode: str = "paged",
                parallel: Optional[ParallelConfig] = None,
                prefix_cache_path: Optional[str] = None,
                **kwargs) -> Engine:
    """Single construction point for serving engines.

    ``mode="paged"`` (default) — the production engine: paged KV arena,
    chunked bucketed prefill, copy-on-write prefix sharing (pass
    ``enable_prefix_cache=False`` to disable), page-occupancy scheduling,
    and optional speculative decoding. Keyword args: max_slots, max_len,
    page_size, num_pages, prefill_chunk, enable_prefix_cache, spec,
    moe_dispatch, exec_cfg, seed.

    ``moe_dispatch`` (paged only) — "dropless" (default) routes every
    serving row through the drop-free MoE dispatch, making greedy tokens
    invariant to prefill chunking/preemption; "capacity" opts back into
    the capacity-bucketed training dispatch for baseline comparison
    (tokens may drop; ``stats().moe.dropped_tokens`` counts them). The
    dense oracle always routes dropless.

    ``parallel`` — a ``ParallelConfig``; ``tp=N`` runs the paged engine
    tensor-parallel over the first N local devices (params, paged KV pool
    and activations sharded; scheduler/prefix/drafter state replicated
    host-side). Omitted (or ``tp=1``) keeps today's single-device
    behavior. The dense oracle rejects ``tp > 1``.

    ``prefix_cache_path`` — persist the prefix index across restarts: if
    the file exists its trie + page contents load into the fresh engine's
    pool at construction; ``engine.save_prefix_cache()`` writes it back.

    ``spec`` enables draft-and-verify decoding on the paged engine: pass a
    ``serve.spec.SpecConfig`` (or the drafter name ``"ngram"`` /
    ``"selfdraft"`` for defaults). ``spec=None`` (the default) leaves the
    engine byte-identical to the non-speculative configuration. Spec runs
    on every architecture: ring/Mamba/RWKV per-slot state is checkpointed
    around each verify chunk (``SlotStateArena``) and a rejection rewinds
    it in lockstep with the paged-KV cursor, replaying the accepted
    prefix as a resumed prefill chunk
    (``stats().spec.recurrent_rollbacks`` counts those).

    ``mode="dense"`` — the dense ``max_batch x max_len`` oracle, kept for
    equivalence testing and as the benchmark baseline (``spec`` is not
    supported there). Keyword args: max_batch, max_len, exec_cfg, seed.
    """
    from repro.serve.engine import DenseServeEngine, PagedServeEngine
    if mode == "paged":
        return PagedServeEngine(cfg, params, adapters, parallel=parallel,
                                prefix_cache_path=prefix_cache_path, **kwargs)
    if mode == "dense":
        if parallel is not None and parallel.tp > 1:
            raise ValueError("tensor parallelism requires mode='paged' (the "
                             "dense oracle is a single-device baseline)")
        if prefix_cache_path is not None:
            raise ValueError("prefix_cache_path requires mode='paged' (the "
                             "dense oracle has no prefix index)")
        if kwargs.get("spec") is not None:
            raise ValueError("spec decoding requires mode='paged' (the "
                             "dense oracle has no rollback support)")
        kwargs.pop("spec", None)
        return DenseServeEngine(cfg, params, adapters, **kwargs)
    raise ValueError(f"unknown engine mode {mode!r} (expected 'paged' or "
                     f"'dense')")
