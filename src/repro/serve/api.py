"""The unified serving surface: one Request/Completion pair, one Engine
protocol, one factory.

Every launch path constructs engines through ``make_engine(cfg, params,
..., mode=...)``; the paged engine owns production serving and the dense
engine survives only as the equivalence oracle / benchmark baseline.

    eng = make_engine(cfg, params, adapters, mode="paged", max_slots=16)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=32))
    completions = eng.drain()          # {uid: Completion}
    print(eng.stats())

Engines implement the ``Engine`` protocol: ``submit`` enqueues (failing
fast on infeasible requests), ``step`` runs one scheduler tick, ``drain``
runs ticks until the queue and slots are empty and returns immutable
``Completion`` records, ``stats`` reports engine counters (the paged
engine adds prefix-cache hit tokens, CoW forks, and page occupancy).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple,\
    runtime_checkable

import numpy as np


@dataclass
class Request:
    """One generation request. ``generated``/``done``/``finish_reason`` are
    filled by the engine as it serves the request."""
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    adapter_id: int = 0
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    done: bool = False
    finish_reason: str = ""             # "length" | "eos" | "capacity"


@dataclass(frozen=True)
class Completion:
    """Immutable result of one finished request."""
    uid: int
    prompt: Tuple[int, ...]
    tokens: Tuple[int, ...]             # generated tokens
    adapter_id: int
    finish_reason: str

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


def completion_of(req: Request) -> Completion:
    return Completion(uid=req.uid,
                      prompt=tuple(int(t) for t in req.prompt),
                      tokens=tuple(req.generated),
                      adapter_id=req.adapter_id,
                      finish_reason=req.finish_reason or "length")


@runtime_checkable
class Engine(Protocol):
    """What every serving engine exposes — nothing else is public API."""

    def submit(self, req: Request) -> None: ...
    def step(self) -> None: ...
    def drain(self, max_ticks: int = 100_000) -> Dict[int, Completion]: ...
    def stats(self) -> Dict[str, object]: ...


def make_engine(cfg, params, adapters: Sequence = (), *,
                mode: str = "paged", **kwargs) -> Engine:
    """Single construction point for serving engines.

    ``mode="paged"`` (default) — the production engine: paged KV arena,
    chunked bucketed prefill, copy-on-write prefix sharing (pass
    ``enable_prefix_cache=False`` to disable), page-occupancy scheduling,
    and optional speculative decoding. Keyword args: max_slots, max_len,
    page_size, num_pages, prefill_chunk, enable_prefix_cache, spec,
    exec_cfg, seed.

    ``spec`` enables draft-and-verify decoding on the paged engine: pass a
    ``serve.spec.SpecConfig`` (or the drafter name ``"ngram"`` /
    ``"selfdraft"`` for defaults). ``spec=None`` (the default) leaves the
    engine byte-identical to the non-speculative configuration; on
    architectures with per-slot ring/recurrent state it auto-disables
    (``stats()["spec_disabled_reason"]`` says why).

    ``mode="dense"`` — the dense ``max_batch x max_len`` oracle, kept for
    equivalence testing and as the benchmark baseline (``spec`` is not
    supported there). Keyword args: max_batch, max_len, exec_cfg, seed.
    """
    from repro.serve.engine import DenseServeEngine, PagedServeEngine
    if mode == "paged":
        return PagedServeEngine(cfg, params, adapters, **kwargs)
    if mode == "dense":
        if kwargs.get("spec") is not None:
            raise ValueError("spec decoding requires mode='paged' (the "
                             "dense oracle has no rollback support)")
        kwargs.pop("spec", None)
        return DenseServeEngine(cfg, params, adapters, **kwargs)
    raise ValueError(f"unknown engine mode {mode!r} (expected 'paged' or "
                     f"'dense')")
