"""Token sampling shared by every serving engine and the spec verifier.

One rule, used everywhere a token is drawn: greedy argmax at temperature
0, seeded Gumbel-max at temperature > 0. Gumbel-max IS categorical
sampling — ``argmax(logits/T + g)`` with ``g ~ Gumbel(0,1)`` draws
exactly from ``softmax(logits/T)`` — which is what makes the spec-decode
rejection rule exact: the correction token must come from the true
target distribution (optionally with the rejected draft token masked
out), not from a temperature-scaled argmax heuristic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def gumbel_like(rng, shape) -> Array:
    """Seeded Gumbel(0,1) noise (the ``minval`` floor avoids log(0))."""
    u = jax.random.uniform(rng, shape, minval=1e-9, maxval=1.0)
    return -jnp.log(-jnp.log(u))


def sample_tokens(logits: Array, temps: Array, rng,
                  forbid: Optional[Array] = None) -> Array:
    """Greedy when temp == 0, categorical (Gumbel-max) otherwise.

    logits (B, V), temps (B,). ``forbid`` (B,) optionally masks one token
    id per row to -inf before sampling — the residual draw of spec-decode
    rejection sampling (with a deterministic drafter the residual of
    ``p`` after rejecting draft ``d`` is exactly ``p`` renormalized over
    ``V \\ {d}``). Pass ``forbid[b] = -1`` to leave row ``b`` unmasked.
    """
    if forbid is not None:
        V = logits.shape[-1]
        hit = (jnp.arange(V)[None, :] == forbid[:, None]) & \
            (forbid[:, None] >= 0)
        logits = jnp.where(hit, -jnp.inf, logits)
    greedy = jnp.argmax(logits, -1)
    gumbel = gumbel_like(rng, logits.shape)
    sampled = jnp.argmax(logits / jnp.maximum(temps[:, None], 1e-6)
                         + gumbel, -1)
    return jnp.where(temps > 0, sampled, greedy)
