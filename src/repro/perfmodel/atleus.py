"""Analytical Atleus hardware model (paper Table IV + SS IV/V methodology).

The paper's own evaluation is deterministic-simulator-based (SCALE-Sim for
the systolic cores, NeuroSim for ReRAM tile peripherals, BookSim2 for the
NoC). This module rebuilds that deterministic model analytically so every
figure in the paper can be regenerated; constants marked [T4] come straight
from Table IV, constants marked [cal] are calibrated within the ranges the
cited tools report (ISAAC/NeuroSim-class ReRAM timing, HBM2 energy).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

# ---------------------------------------------------------------------------
# hardware constants
# ---------------------------------------------------------------------------

XBAR = 128                  # crossbar rows/cols [T4]
CELL_BITS = 2               # bits per ReRAM cell [T4]
XBARS_PER_TILE = 96         # [T4]
TILES_PER_CORE = 16         # [T4]
RERAM_CORES = 16 * 3        # 3 ReRAM tiers x 16 cores [T4/SSV.A]
RERAM_TILE_W = 0.345        # W per tile [T4]
RERAM_TILE_AREA = 0.37      # mm^2 [T4]

SYS_ROWS, SYS_COLS = 128, 32    # PEs per systolic core [T4]
SYS_CORES = 16                  # 1 tier x 16 cores [SSV.A]
SYS_CLOCK = 800e6               # [T4]
SYS_CORE_W = 2.13               # W [T4]
SYS_CORE_AREA = 2.55            # mm^2 [T4]

HBM_BW = 256e9                  # B/s [T4]
HBM_PJ_PER_BYTE = 56.0          # ~7 pJ/bit HBM2 access energy [cal]

# ReRAM tile timing [cal: NeuroSim/ISAAC-class]:
#   one analog MVM pass = DAC streaming (1 bit/cycle) + ADC readout shared
#   across columns + shift&add; ~100 ns per 8-bit-input crossbar MVM.
T_XBAR_MVM_8B = 100e-9          # s per crossbar per 8-bit input vector [cal]
T_DEQUANT_STAGE = 10e-9         # extra S&A pipeline stage (SS IV.D) [cal]
E_XBAR_MVM = 2.4e-9             # J per crossbar MVM (incl. ADC) [cal]
E_SYS_MAC = 0.6e-12             # J per systolic MAC @10nm [cal]

NOC_NS_PER_HOP = 2.0            # router+link latency per hop [cal]
NOC_PJ_PER_BYTE_HOP = 1.0      # [cal]
TSV_NS = 0.5                    # vertical hop [T4-derived]


# ---------------------------------------------------------------------------
# workload description (paper Table II kernels)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TransformerDims:
    name: str
    n_layers: int
    d_model: int
    n: int                   # sequence length
    d_ff: Optional[int] = None
    lora_r: int = 32
    lora_k: int = 2          # LoRA on W_Q and W_V [SSV.A]
    weight_bits: int = 16

    @property
    def ff(self) -> int:
        return self.d_ff if self.d_ff else 4 * self.d_model


def mm_reram_ops(d: TransformerDims) -> float:
    """Eq. 2: MM_ReRAM = 12 * d_model^2 * n (per layer, MACs)."""
    return 12.0 * d.d_model * d.d_model * d.n


def mm_systolic_ops(d: TransformerDims, fine_tuning: bool = True) -> float:
    """Eq. 3: d_model*n^2 (MHA-2/3) + 2k*d_model*r*n (LoRA fwd+bwd) +
    3*d_model*n (nonlinear) — per layer, MACs."""
    ops = float(d.d_model) * d.n * d.n
    if fine_tuning:
        ops += 2.0 * d.lora_k * d.d_model * d.lora_r * d.n
    ops += 3.0 * d.d_model * d.n
    return ops


def reram_share(d: TransformerDims, fine_tuning: bool = True) -> float:
    r = mm_reram_ops(d)
    s = mm_systolic_ops(d, fine_tuning)
    return r / (r + s)


# ---------------------------------------------------------------------------
# engine latency/energy models
# ---------------------------------------------------------------------------

def reram_matmul_time(rows: int, cols: int, n_tokens: int, *,
                      weight_bits: int = 16, input_bits: int = 8,
                      cores: int = 1, layers_resident: int = 1,
                      dequant: bool = False) -> float:
    """Streaming n_tokens input vectors through a (rows x cols) weight on
    ReRAM. The pipelined design keeps EVERY layer's weights resident
    (PipeLayer-style, SS IV.A), so one layer's matmul owns
    cores/layers_resident worth of crossbars:

      * if the weight needs more crossbars than its share, passes are
        time-multiplexed (slowdown);
      * if it needs fewer (e.g. after crossbar-wise quantization halves the
        cells per weight), the weight is *duplicated* for token-parallel
        speedup — "reduced resource requirements or faster-pipelined
        execution with weight duplication" (SS IV.D).

    Throughput-pipelined over the xb_rows accumulation depth: time =
    (n_tokens * mux / dup + xb_rows) * t_pass."""
    cells_per_weight = max(1, weight_bits // CELL_BITS)
    xb_rows = math.ceil(rows / XBAR)
    xb_cols = math.ceil(cols * cells_per_weight / XBAR)
    n_xbar = xb_rows * xb_cols
    budget = cores * TILES_PER_CORE * XBARS_PER_TILE / max(layers_resident, 1)
    dup = max(1.0, budget / n_xbar)
    mux = max(1.0, n_xbar / budget)
    t_pass = T_XBAR_MVM_8B * (input_bits / 8.0)
    if dequant:
        t_pass += T_DEQUANT_STAGE
    return (n_tokens * mux / dup + xb_rows) * t_pass


def reram_matmul_energy(rows: int, cols: int, n_tokens: int, *,
                        weight_bits: int = 16) -> float:
    cells_per_weight = max(1, weight_bits // CELL_BITS)
    xb_rows = math.ceil(rows / XBAR)
    xb_cols = math.ceil(cols * cells_per_weight / XBAR)
    return n_tokens * xb_rows * xb_cols * E_XBAR_MVM


def systolic_matmul_time(M: int, K: int, N: int, *, rows: int = SYS_ROWS,
                         cols: int = SYS_COLS, cores: int = 1,
                         dataflow: str = "OS") -> float:
    """SCALE-Sim-style cycle model. OS keeps partial sums stationary: per
    (rows x cols) output tile the array streams K operands plus fill/drain."""
    m_t = math.ceil(M / rows)
    n_t = math.ceil(N / cols)
    if dataflow == "OS":
        cyc_tile = K + rows + cols - 2
    elif dataflow == "WS":
        cyc_tile = M + rows + cols - 2
        m_t = math.ceil(K / rows)   # weights stationary: K mapped on rows
        n_t = math.ceil(N / cols)
    else:  # IS
        cyc_tile = N + rows + cols - 2
        m_t = math.ceil(K / rows)
        n_t = math.ceil(M / cols)
    tiles = max(1, m_t * n_t)
    cycles = math.ceil(tiles / cores) * cyc_tile
    return cycles / SYS_CLOCK


def systolic_matmul_energy(M: int, K: int, N: int) -> float:
    return 2.0 * M * K * N / 2.0 * E_SYS_MAC  # MACs * E/MAC


def systolic_utilization(M: int, K: int, N: int, rows: int, cols: int,
                         cores: int = 16, dataflow: str = "OS") -> float:
    t = systolic_matmul_time(M, K, N, rows=rows, cols=cols, cores=cores,
                             dataflow=dataflow)
    macs = M * K * N
    peak = rows * cols * SYS_CLOCK * cores
    return macs / (t * peak)


def softmax_time(n_rows: int, n_cols: int) -> float:
    """Fused row-wise score+softmax on the systolic core's vector path."""
    return 3.0 * n_rows * n_cols / (SYS_COLS * SYS_ROWS) / SYS_CLOCK


def hbm_time(bytes_moved: float) -> float:
    return bytes_moved / HBM_BW


def hbm_energy(bytes_moved: float) -> float:
    return bytes_moved * HBM_PJ_PER_BYTE * 1e-12
