"""Intra-layer 4-stage pipeline model (paper SS IV.A, SS V.F / Fig. 10)
and the HAIMA baseline's stage delays.

Atleus stages (resources 3:1 ReRAM:systolic, SS V.A):
  S1  MHA pre-trained projections (W_Q/K/V + W_O)    -> 16 ReRAM cores
  S2  Q.K^T, fused softmax, P.V, LoRA A/B            -> 16 systolic cores
  S3  FF-1 (d -> 4d)                                 -> 16 ReRAM cores
  S4  FF-2 (4d -> d)                                 -> 16 ReRAM cores

HAIMA (DAC'23): SRAM units for dynamic ops, DRAM(HBM)-PIM for the large
weight matmuls, a *host* for softmax over a shared 2.5D interposer —
many-to-one traffic + HBM bank-parallelism limits are what Fig. 10 shows.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.perfmodel import atleus as hw
from repro.perfmodel.atleus import TransformerDims

RERAM_CORES_PER_STAGE = 16
SYS_CORES_S2 = 16
NOC_BW = 64e9            # B/s per vertical/skip link group [cal]
INTERPOSER_BW = 32e9     # HAIMA shared interposer to host [cal]
HBM_BANK_PAR = 4         # HAIMA: concurrent HBM compute banks [58]
HOST_SOFTMAX_FLOPS = 2e12


@dataclass
class StageDelays:
    compute: Dict[str, float]
    comm: Dict[str, float]

    def total(self, s: str) -> float:
        return self.compute[s] + self.comm[s]

    @property
    def bottleneck(self) -> float:
        return max(self.total(s) for s in self.compute)


def atleus_stages(d: TransformerDims, *, fine_tuning: bool = True,
                  mha_bits: int = 16, ff_bits: int = 16) -> StageDelays:
    n, dm, ff = d.n, d.d_model, d.ff
    act = 2  # bf16 activation bytes
    dequant = mha_bits < 16 or ff_bits < 16

    s1 = hw.reram_matmul_time(dm, 4 * dm, n, weight_bits=mha_bits,
                              cores=RERAM_CORES_PER_STAGE,
                              layers_resident=d.n_layers, dequant=dequant)
    # S2: scores (n x dm x n) + PV (n x n x dm) + softmax + LoRA fwd/bwd
    t_sc = hw.systolic_matmul_time(n, dm, n, cores=SYS_CORES_S2)
    t_pv = hw.systolic_matmul_time(n, n, dm, cores=SYS_CORES_S2)
    t_sm = hw.softmax_time(n, n)
    t_lora = 0.0
    if fine_tuning:
        for _ in range(d.lora_k):
            t_lora += 2 * (hw.systolic_matmul_time(n, dm, d.lora_r,
                                                   cores=SYS_CORES_S2)
                           + hw.systolic_matmul_time(n, d.lora_r, dm,
                                                     cores=SYS_CORES_S2))
    s2 = t_sc + t_pv + t_sm + t_lora
    s3 = hw.reram_matmul_time(dm, ff, n, weight_bits=ff_bits,
                              cores=RERAM_CORES_PER_STAGE,
                              layers_resident=d.n_layers, dequant=dequant)
    s4 = hw.reram_matmul_time(ff, dm, n, weight_bits=ff_bits,
                              cores=RERAM_CORES_PER_STAGE,
                              layers_resident=d.n_layers, dequant=dequant)

    # comm: activations hop between stages over TSV/skip links (1-2 hops)
    c_act = n * dm * act / NOC_BW
    c_kv = 3 * n * dm * act / NOC_BW          # Q,K,V to systolic
    c_ff = n * ff * act / NOC_BW
    return StageDelays(
        compute={"S1": s1, "S2": s2, "S3": s3, "S4": s4},
        comm={"S1": c_act, "S2": c_kv, "S3": c_act, "S4": c_ff})


def haima_stages(d: TransformerDims, *, fine_tuning: bool = True,
                 quant_bits: int = 16) -> StageDelays:
    n, dm, ff = d.n, d.d_model, d.ff
    act = 2
    dequant_pre = 1.3 if quant_bits < 16 else 1.0  # dequant before compute

    # HBM-PIM matmuls: Newton-class AiM, bank-parallelism-limited [58]
    hbm_eff = 2.0e12
    s1 = dequant_pre * (2.0 * n * dm * 4 * dm) / hbm_eff
    # S2: K,Q on HBM, V on SRAM; scores shipped to the host for softmax
    t_sc = (2.0 * n * dm * n) / hbm_eff
    t_sm = 3.0 * n * n / HOST_SOFTMAX_FLOPS
    t_lora = 0.0
    if fine_tuning:
        t_lora = sum(2 * (2.0 * n * dm * d.lora_r + 2.0 * n * d.lora_r * dm)
                     for _ in range(d.lora_k)) / hbm_eff
    s2 = t_sc + t_sm + t_lora
    s3 = dequant_pre * (2.0 * n * dm * ff) / hbm_eff
    s4 = dequant_pre * (2.0 * n * ff * dm) / hbm_eff

    # comm: many-to-one over the shared interposer (host + SRAM exchange)
    c1 = 3 * n * dm * act / INTERPOSER_BW
    c2 = 2 * (n * n * 2 + n * dm) * act / INTERPOSER_BW  # scores out+back
    c3 = n * dm * act / INTERPOSER_BW
    c4 = n * ff * act / INTERPOSER_BW
    return StageDelays(
        compute={"S1": s1, "S2": s2, "S3": s3, "S4": s4},
        comm={"S1": c1, "S2": c2, "S3": c3, "S4": c4})


def end_to_end_time(stages: StageDelays, n_layers: int, n_batches: int
                    ) -> float:
    """Pipelined execution: fill (4 stages x layers) + steady state."""
    fill = sum(stages.total(s) for s in stages.compute)
    return fill * 1 + stages.bottleneck * max(0, n_layers * n_batches - 1)


def atleus_layer_energy(d: TransformerDims, *, mha_bits=16, ff_bits=16,
                        fine_tuning=True) -> Dict[str, float]:
    n, dm, ff = d.n, d.d_model, d.ff
    e_reram = (hw.reram_matmul_energy(dm, 4 * dm, n, weight_bits=mha_bits)
               + hw.reram_matmul_energy(dm, ff, n, weight_bits=ff_bits)
               + hw.reram_matmul_energy(ff, dm, n, weight_bits=ff_bits))
    e_sys = (hw.systolic_matmul_energy(n, dm, n)
             + hw.systolic_matmul_energy(n, n, dm))
    if fine_tuning:
        e_sys += sum(2 * (hw.systolic_matmul_energy(n, dm, d.lora_r)
                          + hw.systolic_matmul_energy(n, d.lora_r, dm))
                     for _ in range(d.lora_k))
    return {"reram": e_reram, "systolic": e_sys}
