"""Die-yield and 3D-stack cost model (paper Eqs. 6-11, SS V.D).

Pure math — no calibration: N_die from wafer geometry, Bose-Einstein-style
clustered-defect yield, 3D stacking yield, TSV keep-out area.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# paper-stated physical parameters [SS V.D]
D0 = 0.2          # defects / cm^2
ALPHA = 3.0       # clustering
WAFER_MM = 300.0  # wafer diameter (the paper's "300nm" is a typo for mm)
Y_WAFER = 1.0
Y_STACKING = 0.98
Y_TSV = 0.99
TSV_PITCH_FACTOR = 3.0  # pitch = 3 * diameter [52]


def n_die(area_mm2: float, wafer_mm: float = WAFER_MM) -> float:
    """Eq. 7."""
    r = wafer_mm / 2.0
    return (math.pi * r * r / area_mm2
            - math.pi * wafer_mm / math.sqrt(2.0 * area_mm2))


def die_yield(area_mm2: float, d0: float = D0, alpha: float = ALPHA) -> float:
    """Eq. 8 (D0 per cm^2 -> area in cm^2)."""
    a_cm2 = area_mm2 / 100.0
    return Y_WAFER * (1.0 + a_cm2 * d0 / alpha) ** (-alpha)


def die_cost(area_mm2: float, wafer_cost: float = 1.0) -> float:
    """Eq. 6 (relative units)."""
    return (wafer_cost / n_die(area_mm2)) / die_yield(area_mm2)


def cost_3d(tier_areas_mm2, y_stacking: float = Y_STACKING,
            y_tsv: float = Y_TSV) -> float:
    """Eq. 9."""
    n = len(tier_areas_mm2)
    return sum(die_cost(a) for a in tier_areas_mm2) / (
        y_stacking ** (n - 1) * y_tsv)


def normalized_die_cost(area_a: float, area_b: float) -> float:
    """Eq. 10: cost(A) relative to cost(B)."""
    return (die_yield(area_b) * n_die(area_b)) / (
        die_yield(area_a) * n_die(area_a))


def tsv_area_mm2(n_tsv: int, diameter_um: float) -> float:
    """Eq. 11 third term: keep-out = pitch^2 per TSV."""
    pitch_mm = TSV_PITCH_FACTOR * diameter_um * 1e-3
    return n_tsv * pitch_mm * pitch_mm


def compare_2d_vs_3d(tier_mm2: float = 100.0, n_tiers: int = 4):
    """SS V.D: four 100 mm^2 tiers vs one 400 mm^2 2D die.

    Returns (cost_3d, cost_2d, ratio). The paper reports the 2D die cost
    ~67% higher than the summed 3D tier cost."""
    c3d = cost_3d([tier_mm2] * n_tiers)
    c2d = die_cost(tier_mm2 * n_tiers)
    return c3d, c2d, c2d / c3d
