"""NoC model (paper SS IV.B, SS V.D / Fig. 8): 3D-mesh vs 3D-mesh+skip vs
Atleus (SFC ReRAM tiers + mesh systolic tier + skip TSVs).

Port histograms and hop counts are exact for the 4-tier x (4x4) system;
router area scales with the switch crossbar (∝ ports^2), TSV keep-out from
the cost model (skip TSVs span 3 tiers -> 3x diameter at constant aspect
ratio -> 9x keep-out). EDP combines average hop latency and per-hop energy
over the paper's traffic mix (inter-layer activation flow along consecutive
cores + intra-layer ReRAM<->systolic exchange + DRAM access on the bottom
tier).
"""
from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.perfmodel import cost as cost_mod
from repro.perfmodel.atleus import (NOC_NS_PER_HOP, NOC_PJ_PER_BYTE_HOP,
                                    RERAM_TILE_AREA, SYS_CORE_AREA, TILES_PER_CORE,
                                    TSV_NS)

GRID = 4                    # 4x4 cores per tier
TIERS = 4                   # 3 ReRAM + 1 systolic
TSV_DIAM_UM = 5.0           # [T4]
ROUTER_AREA_PER_PORT = 0.00033  # mm^2 per port (buffers dominate) [cal]
EDP_FLOOR = 0.7586              # hop-independent share of latency & energy
                                # (injection/ejection, serialization) [cal]

# traffic mix (bytes fraction): inter-layer activation forwarding along
# consecutive cores; intra-layer ReRAM->systolic->ReRAM; DRAM access.
# Fine-tuning traffic is DRAM-access dominated (input pipeline, systolic
# weight streaming, LoRA activation/gradient spill); the on-chip classes
# split the rest. Calibrated against Fig. 8(b)'s BookSim results.
TRAFFIC = {"inter_layer": 0.18, "intra_layer": 0.088, "dram": 0.732}


def _planar_ports_mesh(x: int, y: int) -> int:
    return sum(1 for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1))
               if 0 <= x + dx < GRID and 0 <= y + dy < GRID)


def _snake_index(x: int, y: int) -> int:
    return y * GRID + (x if y % 2 == 0 else GRID - 1 - x)


def router_ports(config: str) -> List[int]:
    """Port count per router (local port included) for all 64 routers."""
    ports = []
    for z in range(TIERS):
        for y in range(GRID):
            for x in range(GRID):
                p = 1  # local
                vertical = (1 if z in (0, TIERS - 1) else 2)
                p += vertical
                is_reram = z > 0          # tier 0 = systolic (bottom)
                if config == "atleus" and is_reram:
                    idx = _snake_index(x, y)
                    p += (1 if idx in (0, GRID * GRID - 1) else 2)  # SFC
                else:
                    p += _planar_ports_mesh(x, y)
                if config in ("mesh_skip", "atleus") and z in (0, TIERS - 1):
                    p += 1               # skip TSV top<->bottom
                ports.append(p)
    return ports


def port_histogram(config: str) -> Dict[int, int]:
    return dict(sorted(Counter(router_ports(config)).items()))


def _avg_hops(config: str) -> Dict[str, float]:
    """Average hops per traffic class."""
    # inter-layer: consecutive cores. Mesh: consecutive layer cores placed
    # row-major -> wrap rows cost (GRID-1) extra hops every GRID-th step.
    mesh_inter = ((GRID - 1) * 1.0 + 1 * (GRID - 1)) / GRID  # avg ~1.75
    sfc_inter = 1.0                                           # snake: always 1
    # intra-layer: ReRAM tier z in {1,2,3} to systolic tier 0 and back.
    # mesh: vertical hops = z (avg 2) + planar alignment (avg GRID/2)
    mesh_intra = 2.0 + GRID / 2.0
    skip_intra = 1.0 + 1.0      # skip TSV from top tier; middle tiers 1-2
    # dram: bottom tier mesh to edge memory controller
    dram = GRID / 2.0
    if config == "mesh":
        return {"inter_layer": mesh_inter, "intra_layer": mesh_intra,
                "dram": dram}
    if config == "mesh_skip":
        return {"inter_layer": mesh_inter, "intra_layer": skip_intra + 0.5,
                "dram": dram}
    return {"inter_layer": sfc_inter, "intra_layer": skip_intra, "dram": dram}


def _router_factor(config: str) -> float:
    """Switch crossbar complexity grows with ports^2: bigger routers
    arbitrate slower and burn more per flit."""
    ports = router_ports(config)
    base = router_ports("mesh")
    r = (sum(ports) / len(ports)) / (sum(base) / len(base))
    return r * r


def edp(config: str) -> float:
    hops = _avg_hops(config)
    w = sum(TRAFFIC[k] * hops[k] for k in TRAFFIC)
    lat = w * NOC_NS_PER_HOP
    energy = w * NOC_PJ_PER_BYTE_HOP
    return lat * energy


def noc_area(config: str) -> float:
    """Router + TSV keep-out area (mm^2, whole stack)."""
    r_area = sum(ROUTER_AREA_PER_PORT * p for p in router_ports(config))
    tsv = cost_mod.tsv_area_mm2(48 * (TIERS - 1), TSV_DIAM_UM)
    if config in ("mesh_skip", "atleus"):
        # skip TSVs span the stack: larger diameter at bounded aspect ratio
        tsv += cost_mod.tsv_area_mm2(16, 2 * TSV_DIAM_UM)
    return r_area + tsv


def tier_area(config: str) -> float:
    """One tier's die area: cores + its share of NoC area."""
    core = max(RERAM_TILE_AREA * TILES_PER_CORE, SYS_CORE_AREA) * GRID * GRID
    return core + noc_area(config) / TIERS


def compare() -> Dict[str, Dict[str, float]]:
    """Fig. 8(b): EDP / area / cost normalized to the 3D-mesh baseline."""
    out = {}
    base_edp = edp("mesh")
    base_area = noc_area("mesh")
    base_cost = cost_mod.cost_3d([tier_area("mesh")] * TIERS)
    for c in ("mesh", "mesh_skip", "atleus"):
        out[c] = {
            "edp": edp(c) / base_edp,
            "noc_area": noc_area(c) / base_area,
            "cost": cost_mod.cost_3d([tier_area(c)] * TIERS) / base_cost,
            "ports": port_histogram(c),
        }
    return out
