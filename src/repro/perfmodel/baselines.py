"""End-to-end latency/energy baselines (paper Figs. 11, 12, 14, 15):
Atleus vs HAIMA vs 3D-TPU vs GPU (V100), plus quantization trendlines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.perfmodel import atleus as hw, pipeline as pipe
from repro.perfmodel.atleus import TransformerDims

# V100 [paper SSV.F: <50% utilization for fine-tuning]
GPU_PEAK = 125e12
GPU_UTIL_FT = 0.028   # small-batch FT FLOP efficiency [cal to Fig.11]
GPU_W = 120.0   # V100 draw at few-% utilization [cal]
GPU_DEQ_OVERHEAD = 0.30     # runtime overhead per quantized matmul
# 3D-TPU: 4 tiers x (2x2) cores of 128x128 @ SYS_CLOCK, same SRAM [SSV.A]
TPU3D_CORES = 16
TPU3D_PEAK = TPU3D_CORES * 128 * 128 * 2 * hw.SYS_CLOCK
TPU3D_UTIL = 0.0167         # "~2x faster than GPU" [SSV.F]
TPU3D_W = 160.0
ATLEUS_W = (48 * hw.TILES_PER_CORE * hw.RERAM_TILE_W / 3    # active tier mix
            + hw.SYS_CORES * hw.SYS_CORE_W)


def _layer_flops(d: TransformerDims, fine_tuning: bool) -> float:
    return 2.0 * (hw.mm_reram_ops(d) + hw.mm_systolic_ops(d, fine_tuning))


def atleus_time_energy(d: TransformerDims, *, n_batches: int = 1,
                       fine_tuning: bool = True, mha_bits: int = 16,
                       ff_bits: int = 16) -> Dict[str, float]:
    st = pipe.atleus_stages(d, fine_tuning=fine_tuning, mha_bits=mha_bits,
                            ff_bits=ff_bits)
    bwd = 2.2 if fine_tuning else 1.0   # backward through frozen base
    t = bwd * pipe.end_to_end_time(st, d.n_layers, n_batches)
    e_layer = pipe.atleus_layer_energy(d, mha_bits=mha_bits, ff_bits=ff_bits,
                                       fine_tuning=fine_tuning)
    # quantized weights use proportionally fewer cells -> pro-rated energy;
    # the extra dequant S&A stage costs ~1.5% power (SS IV.D)
    scale_mha = (mha_bits / 16.0) * 1.015 if mha_bits < 16 else 1.0
    scale_ff = (ff_bits / 16.0) * 1.015 if ff_bits < 16 else 1.0
    e_reram = e_layer["reram"] * (0.33 * scale_mha + 0.67 * scale_ff)
    e = bwd * d.n_layers * n_batches * (e_reram + e_layer["systolic"])
    e += hw.hbm_energy(2.0 * d.lora_k * d.d_model * d.lora_r * 4 * n_batches)
    return {"time": t, "energy": e + ATLEUS_W * 0.1 * t}  # +NoC/static


def haima_time_energy(d: TransformerDims, *, n_batches: int = 1,
                      fine_tuning: bool = True, quant_bits: int = 16
                      ) -> Dict[str, float]:
    st = pipe.haima_stages(d, fine_tuning=fine_tuning, quant_bits=quant_bits)
    bwd = 2.2 if fine_tuning else 1.0
    # HBM multiplexing prevents layer-level pipelining (SS V.F)
    t = bwd * sum(st.total(s) for s in st.compute) * d.n_layers * n_batches
    flops = _layer_flops(d, fine_tuning) * d.n_layers * n_batches * bwd
    e = hw.hbm_energy(flops / 4.0) + 60.0 * t   # PIM ~HBM-access-bound
    if quant_bits < 16:
        e *= 1.0 + 0.15                          # dequant in DRAM adds energy
    return {"time": t, "energy": e}


def gpu_time_energy(d: TransformerDims, *, n_batches: int = 1,
                    fine_tuning: bool = True, quant_bits: int = 16
                    ) -> Dict[str, float]:
    bwd = 3.0 if fine_tuning else 1.0
    flops = _layer_flops(d, fine_tuning) * d.n_layers * n_batches * bwd
    t = flops / (GPU_PEAK * GPU_UTIL_FT)
    if quant_bits < 16:
        t *= 1.0 + GPU_DEQ_OVERHEAD              # dequantize-then-compute
    return {"time": t, "energy": GPU_W * t}


def tpu3d_time_energy(d: TransformerDims, *, n_batches: int = 1,
                      fine_tuning: bool = True, quant_bits: int = 16
                      ) -> Dict[str, float]:
    bwd = 3.0 if fine_tuning else 1.0
    flops = _layer_flops(d, fine_tuning) * d.n_layers * n_batches * bwd
    t = flops / (TPU3D_PEAK * TPU3D_UTIL)
    if quant_bits < 16:
        t *= 1.0 + 0.2
    return {"time": t, "energy": TPU3D_W * t}


BASELINES = {"atleus": atleus_time_energy, "haima": haima_time_energy,
             "3d-tpu": tpu3d_time_energy, "gpu": gpu_time_energy}


def quant_energy_trend(d: TransformerDims, configs=None) -> Dict[str, Dict[str, float]]:
    """Figs. 12/14: energy per MnFm config normalized to 16-bit, per system."""
    configs = configs or {"M16F16": (16, 16), "M8F8": (8, 8),
                          "M8F4": (8, 4), "M4F8": (4, 8), "M4F4": (4, 4)}
    out: Dict[str, Dict[str, float]] = {}
    base_at = atleus_time_energy(d)["energy"]
    base_gpu = gpu_time_energy(d)["energy"]
    base_tpu = tpu3d_time_energy(d)["energy"]
    base_hai = haima_time_energy(d)["energy"]
    for tag, (mb, fb) in configs.items():
        qb = min(mb, fb)
        out[tag] = {
            "atleus": atleus_time_energy(d, mha_bits=mb, ff_bits=fb)["energy"] / base_at,
            "gpu": gpu_time_energy(d, quant_bits=qb)["energy"] / base_gpu,
            "3d-tpu": tpu3d_time_energy(d, quant_bits=qb)["energy"] / base_tpu,
            "haima": haima_time_energy(d, quant_bits=qb)["energy"] / base_hai,
        }
    return out
