"""GPipe-style pipeline parallelism over a named mesh axis.

The stage weights live sharded over the ``stage`` axis; microbatches march
through the pipeline one tick at a time, with ``ppermute`` moving
activations stage -> stage+1. Total ticks = n_micro + n_stages - 1 (fill +
drain); the classic GPipe bubble.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn, mesh, axis: str, n_micro: int):
    """Build ``f(Ws, x) -> y`` running ``stage_fn(W, x)`` per stage.

    Ws: (n_stages, ...) stage weights (sharded over ``axis``).
    x:  (n_micro, mb, ...) microbatched input (replicated).
    Returns y with the same shape as x: every microbatch pushed through all
    stages in order, matching the sequential composition numerically.
    """
    n_stages = mesh.shape[axis]

    def local(W, x):
        idx = jax.lax.axis_index(axis)
        W0 = W[0]                      # this stage's weights (leading dim 1)
        n_ticks = n_micro + n_stages - 1
        buf0 = jnp.zeros(x.shape[1:], x.dtype)
        outs0 = jnp.zeros_like(x)
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage s processes microbatch m = t - s this tick (if valid);
            # stage 0 ingests fresh microbatches, others read the pipeline
            m = t - idx
            inp = jnp.where(idx == 0, x[jnp.clip(t, 0, n_micro - 1)], buf)
            y = stage_fn(W0, inp)
            nxt = jax.lax.ppermute(y, axis, fwd)
            done = (idx == n_stages - 1) & (m >= 0) & (m < n_micro)
            outs = jnp.where(done,
                             outs.at[jnp.clip(m, 0, n_micro - 1)].set(y),
                             outs)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(n_ticks))
        # results live on the last stage only; broadcast to all
        return jax.lax.psum(jnp.where(idx == n_stages - 1, outs, 0), axis)

    return shard_map(local, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P(), check_rep=False)
