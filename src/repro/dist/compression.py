"""Compressed gradient all-reduce with error feedback.

Cross-pod (DCN) gradient traffic is the scaling bottleneck for the
data-parallel axis; int8 absmax quantization cuts it 4x vs f32. The
quantization residual is fed back into the next round (error feedback),
which keeps SGD convergence unbiased in expectation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def make_compressed_allreduce(mesh, axis: str, bits: int = 8):
    """Returns ``ar(grads, err=None) -> (avg, new_err)``.

    ``grads`` is any pytree of f32 arrays, replicated across ``axis``.
    Each tensor is absmax-quantized to ``bits`` (symmetric), mean-reduced
    over the mesh axis, and the local quantization residual is returned for
    error feedback on the next call.
    """
    qmax = float(2 ** (bits - 1) - 1)
    n_dev = mesh.shape[axis]

    def _one(g, e):
        x = g.astype(jnp.float32) + e.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / qmax, 1e-12)
        deq = jnp.clip(jnp.round(x / scale), -qmax, qmax) * scale
        avg = jax.lax.psum(deq, axis) / n_dev
        return avg.astype(g.dtype), (x - deq).astype(g.dtype)

    def _run(flat_g, flat_e):
        outs = [_one(g, e) for g, e in zip(flat_g, flat_e)]
        return tuple(a for a, _ in outs), tuple(e for _, e in outs)

    def ar(grads, err: Optional[object] = None) -> Tuple[object, object]:
        if err is None:
            err = jax.tree.map(jnp.zeros_like, grads)
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err)
        spec = (P(),) * len(flat_g)
        run = shard_map(_run, mesh=mesh,
                        in_specs=(spec, spec), out_specs=(spec, spec),
                        check_rep=False)
        avg_flat, err_flat = run(tuple(flat_g), tuple(flat_e))
        return treedef.unflatten(avg_flat), treedef.unflatten(err_flat)

    return ar
