"""Distributed execution: GSPMD sharding rules, fault tolerance, gradient
compression, pipeline parallelism."""
