"""Fault tolerance primitives for the training fleet.

Two concerns (DESIGN.md deployment story):

  * restarts — a step failure triggers restore-from-checkpoint; only the
    LoRA adapters + optimizer moments move (megabytes), so the restart
    budget is generous.
  * stragglers — a step that runs far slower than the EMA is first observed
    (could be a transient), then — after ``straggler_patience`` consecutive
    slow steps — the coordinator requests a spare swap.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class RestartPolicy:
    max_restarts: int = 3            # give up after this many step failures
    straggler_factor: float = 3.0    # dt > factor * EMA counts as straggling
    straggler_patience: int = 3      # consecutive slow steps before swapping
    ema_decay: float = 0.9


class FaultCoordinator:
    """Tracks step health; decides observe / swap_spare / restart actions."""

    def __init__(self, policy: Optional[RestartPolicy] = None):
        self.policy = policy or RestartPolicy()
        self.restarts = 0
        self.decisions: List[Dict] = []
        self._ema: Optional[float] = None
        self._slow_streak = 0

    def on_step(self, step: int, dt: float) -> Optional[str]:
        """Feed one step duration; returns an action string when the step
        looks like a straggler, else None. The EMA only absorbs healthy
        steps so a long straggler run cannot normalize itself."""
        p = self.policy
        if self._ema is None:
            self._ema = dt
            return None
        if dt > p.straggler_factor * self._ema:
            self._slow_streak += 1
            action = ("swap_spare" if self._slow_streak >= p.straggler_patience
                      else "observe")
            self.decisions.append({"step": step, "action": action,
                                   "dt": dt, "ema": self._ema})
            if action == "swap_spare":
                self._slow_streak = 0
            return action
        self._slow_streak = 0
        self._ema = p.ema_decay * self._ema + (1 - p.ema_decay) * dt
        return None

    def should_restart(self, exc: BaseException) -> bool:
        """Account one step failure; True while the restart budget lasts."""
        self.restarts += 1
        self.decisions.append({"action": "restart", "n": self.restarts,
                               "exc": type(exc).__name__})
        return self.restarts <= self.policy.max_restarts
