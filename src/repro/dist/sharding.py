"""GSPMD sharding rules: one table for activations, one for parameters.

Axis convention (DESIGN.md SS4): the mesh carries batch-ish axes (``data``,
optionally ``pod``) and one tensor axis (``model``). Activations shard
batch over data axes and the feature/head dim over ``model``; weights shard
their model-parallel dim over ``model``. Decode KV caches shard head_dim
(the seq-append ``dynamic_update_slice`` then lands on an unsharded axis).

Everything here is a *constraint* (``with_sharding_constraint``) — GSPMD
inserts the collectives; numerics are identical to single-device execution.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]          # batch axes (data, pod, ...)
    tp: Optional[str]            # tensor-model axis ("model") or None


def axes_for(mesh) -> MeshAxes:
    names = tuple(mesh.axis_names)
    tp = "model" if "model" in names else None
    return MeshAxes(dp=tuple(n for n in names if n != tp), tp=tp)


# ---------------------------------------------------------------------------
# activation sharder
# ---------------------------------------------------------------------------

def _act_table(dp, tp, seq_tp):
    """name -> per-dim assignment. ``dp`` may be a tuple of axes (data+pod).

    flash_* names carry a ``_f`` suffix inside banded attention where the
    (B, n_q_blocks) dims are folded together — the folded batch stays on dp
    and the short band dims replicate.
    """
    t = {
        # transformer trunk
        "act": (dp, None, tp),
        "act_gathered": (dp, None, None),
        "pos": (dp, None),
        "pos_gathered": (dp, None),
        "logits": (dp, None, tp),
        # attention
        "kv_cache": (dp, None, None, tp),       # (B, Hkv, S, D): hd on tp
        "decode_q": (dp, None, None, tp),
        "kv_gathered": (dp, None, None, None),
        "attn_scores": (dp, None, None, None, None),
        "flash_q": (dp, seq_tp, None, None, None),
        "flash_kv": (None, dp, None, None, None),
        "flash_pb": (None, dp, None),
        "flash_ml": (dp, None, None, seq_tp),
        "flash_acc": (dp, seq_tp, None, None, None),
        # recurrent state
        "ssm_state": (dp, tp, None),
        "ssm_chunks": (None, dp, None, tp),
        "wkv_state": (dp, tp, None, None),
        "wkv_chunks": (None, None, dp, tp, None),
        # MoE: slots over tp (EP), tokens over dp
        "moe_tokens": (dp, None, None),
        "moe_dispatch": (dp, None, tp, None),
        "moe_buffer": (tp, dp, None, None),
        # paged serving (single fleet host per pool today; batch over dp)
        "paged_pool": (None, None, None, tp),
        "paged_q": (dp, None, None, tp),
    }
    for name in ("flash_q", "flash_kv", "flash_pb", "flash_ml", "flash_acc"):
        t[name + "_f"] = tuple(None if (a is seq_tp and a is not None) else a
                               for a in t[name])
    return t


def make_sharder(mesh, axes: MeshAxes, mode: str, *, shard_batch: bool = True
                 ) -> Callable:
    """Returns ``sharder(x, name) -> x`` applying the rule table.

    ``mode``: train | prefill | decode. Sequence-parallel Q sharding only
    applies when T is long (train/prefill); decode replicates the single
    query position. Unknown names or rank mismatches pass through unsharded
    rather than erroring — new call sites degrade gracefully.
    """
    dp = tuple(axes.dp) if (shard_batch and axes.dp) else None
    tp = axes.tp
    seq_tp = tp if mode in ("train", "prefill") else None
    table = _act_table(dp, tp, seq_tp)

    def sharder(x, name: str):
        spec = table.get(name)
        if spec is None or len(spec) != getattr(x, "ndim", -1):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return sharder


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

# weight name -> ranked spec templates; "tp" marks the model-parallel dim.
# Rank includes the leading scan-period stack dim for layer weights.
_COL = {"wq", "wk", "wv", "w1", "w3", "in_proj", "dt_proj", "conv_w",
        "r_proj", "k_proj", "v_proj", "g_proj", "ck_proj", "cr_proj"}
_ROW = {"wo", "w2", "out_proj", "x_proj", "o_proj", "cv_proj"}


def _param_spec(names, shape, tp) -> Optional[P]:
    """names: path keys innermost-last. Quantized leaves sit one level under
    the weight name (…/wq/{q,scale}); scan from the end for a known name."""
    if tp is None:
        return None
    for name in reversed(names):
        if name == "table":                       # embed (V, d)
            return P(tp, None) if len(shape) == 2 else None
        if name == "unembed":                     # (d, V)
            return P(None, tp) if len(shape) == 2 else None
        if name in _COL or name in _ROW:
            nd = len(shape)
            # MoE expert stacks: (n_sp, slots, d, ff) — shard slots (EP)
            if name in ("w1", "w2", "w3") and nd == 4:
                return P(None, tp, None, None)
            if name in _COL:
                if nd == 3:
                    return P(None, None, tp)      # (n_sp, d_in, d_out)
                if nd == 2:
                    return P(None, tp)            # unstacked / bias-like
            else:
                if nd == 3:
                    return P(None, tp, None)
                if nd == 2:
                    return P(tp, None)
            return None
    return None


def params_shardings(cfg: ModelConfig, shapes, mesh, axes: MeshAxes,
                     mode: str, *, shard_batch: bool = True):
    """NamedSharding tree matching a param (shape) tree. Unrecognized or
    small leaves replicate — correctness never depends on this table."""
    tp = axes.tp
    repl = NamedSharding(mesh, P())

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        names = [n for n in names if isinstance(n, str)]
        spec = _param_spec(names, leaf.shape, tp)
        if spec is None:
            return repl
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, shapes)


def cache_shardings(cfg: ModelConfig, mesh, axes: MeshAxes, *,
                    shard_batch: bool = True):
    """``sharding_fn(pos, leaf_name, full_shape)`` for cache_spec_structs.

    Cache leaves carry a leading scan-stack dim: k/v (n_sp, B, Hkv, S, D).
    Head_dim shards over tp; batch over dp."""
    dp = tuple(axes.dp) if (shard_batch and axes.dp) else None
    tp = axes.tp
    table = {
        "k": (None, dp, None, None, tp),
        "v": (None, dp, None, None, tp),
        "len": (None, dp),
        "conv": (None, dp, None, tp),
        "ssm": (None, dp, tp, None),
        "shift_t": (None, dp, tp),
        "shift_c": (None, dp, tp),
        "wkv": (None, dp, tp, None, None),
        # paged pools: (n_sp, n_pages, Hkv, page, D)
        "kp": (None, None, None, None, tp),
        "vp": (None, None, None, None, tp),
    }

    def sharding_fn(pos, name, shape):
        spec = table.get(name)
        if spec is None or len(spec) != len(shape):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*spec))

    return sharding_fn


def guard_divisible(shardings, shapes):
    """Replace any ``NamedSharding`` whose partitioned dims do not divide
    the leaf shape with full replication on the same mesh.

    ``device_put`` requires even divisibility; small / reduced configs
    routinely violate it (a 257-token vocab over tp=2, expert slots not a
    multiple of the mesh width). Correctness never depends on placement,
    so the fallback is always safe — it just costs replicated memory for
    that one leaf."""
    def ok(sh, shape):
        if not isinstance(sh, NamedSharding):
            return True
        for dim, axis in enumerate(sh.spec):
            if axis is None:
                continue
            names = axis if isinstance(axis, tuple) else (axis,)
            width = 1
            for n in names:
                width *= sh.mesh.shape[n]
            if dim >= len(shape) or shape[dim] % width != 0:
                return False
        return True

    def guard(sh, leaf):
        if ok(sh, leaf.shape):
            return sh
        return NamedSharding(sh.mesh, P())

    return jax.tree.map(guard, shardings, shapes)


def needs_fsdp(cfg: ModelConfig, mesh, axes: MeshAxes, *,
               hbm_bytes: float = 32e9, dtype_bytes: int = 2) -> bool:
    """True when tp-sharded params alone would overflow ~60% of one chip —
    the point where the dp axis must also shard weights (FSDP)."""
    tp_w = mesh.shape[axes.tp] if axes.tp else 1
    return cfg.param_count() * dtype_bytes / tp_w > 0.6 * hbm_bytes
