"""Atleus reproduction: heterogeneous quantized PEFT framework in JAX.

Core ideas (DESIGN.md): STATIC/DYNAMIC compute partitioning, crossbar-wise
quantization with post-accumulation dequant, LoRA/QLoRA fine-tuning with a
write-once base, noise-aware fine-tuning, pipelined multi-pod execution.
"""
__version__ = "1.0.0"
