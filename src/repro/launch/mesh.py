"""Production mesh builders.

Importing this module never touches jax device state; meshes are built by
functions only (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def _mesh_kwargs(n_axes: int):
    # jax < 0.5 has no AxisType; Auto is the default behaviour there anyway
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods = 512
    chips (pod, data, model); the pod axis is a second (DCN) data axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(model: Optional[int] = None):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    model = model or 1
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))


def make_tp_mesh(tp: int):
    """(1, tp) serving mesh over the FIRST ``tp`` local devices.

    Unlike ``make_mesh``/``make_host_mesh`` this does not require the mesh
    to cover every device — a tp=2 engine on a 4-device host uses devices
    0..1 and leaves the rest free (e.g. for a second engine)."""
    n = jax.device_count()
    if tp < 1 or tp > n:
        raise ValueError(f"tp={tp} needs 1..{n} local devices")
    devs = np.asarray(jax.devices()[:tp], dtype=object).reshape(1, tp)
    return jax.sharding.Mesh(devs, ("data", "model"),
                             **_mesh_kwargs(2))
