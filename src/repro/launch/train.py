"""Training launcher.

CPU-scale run (this container):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 100 --batch 8 --seq 128

Production pods: the same entrypoint builds the (data, model) mesh from
``jax.devices()``, shards params via ``repro.dist.sharding`` and runs the
identical Trainer (the dry-run proves the lowering for the full configs).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import quant as quant_lib
from repro.core.noise import NoiseConfig
from repro.data.pipeline import make_dataset
from repro.models.transformer import ExecConfig, init_params
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.train.steps import TrainHParams
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant", default="bf16", help="bf16 | M8F8 | M8F4 | ...")
    ap.add_argument("--noise-sigma", type=float, default=0.0,
                    help="noise-aware fine-tuning sigma_rel")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data", default=None, help="memmap token file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.quant != "bf16":
        import re
        m = re.fullmatch(r"M(\d+)F(\d+)", args.quant)
        qc = QuantConfig(mha_bits=int(m.group(1)), ff_bits=int(m.group(2)))
        params = quant_lib.quantize_params(params, qc, min_size=1)
        print(f"quantized base ({qc.tag})")

    noise = NoiseConfig(enabled=args.noise_sigma > 0,
                        sigma_rel=args.noise_sigma)
    ec = ExecConfig(noise=noise, capacity_factor=2.0)
    hp = TrainHParams(
        microbatches=args.microbatches,
        adamw=AdamWConfig(lr=args.lr,
                          schedule=warmup_cosine(args.steps // 10, args.steps)))
    tc = TrainerConfig(seq_len=args.seq, global_batch=args.batch,
                       steps=args.steps, ckpt_dir=args.ckpt_dir,
                       hparams=hp, seed=args.seed)
    ds = make_dataset(cfg.vocab_size, args.seed, args.data)
    tr = Trainer(cfg, tc, ds, exec_cfg=ec, params=params)
    tr.maybe_restore()
    log = tr.run_with_restarts()
    print(f"done: {len(log)} steps, loss {log[0]['loss']:.4f} -> "
          f"{log[-1]['loss']:.4f}")
    return log


if __name__ == "__main__":
    main()
