"""Serving launcher: batched multi-adapter LoRA inference.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --adapters 2 --max-new 16

  # paged arena + chunked prefill (production engine):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --paged --page-size 16 --num-pages 128 --prefill-chunk 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models.transformer import init_params
from repro.serve.engine import PagedServeEngine, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--adapters", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV arena + chunked bucketed prefill")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size (default: half the dense arena)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i + 1))
                for i in range(args.adapters)]
    if args.paged:
        eng = PagedServeEngine(cfg, params, adapters=adapters,
                               max_slots=args.max_batch,
                               max_len=args.max_len,
                               page_size=args.page_size,
                               num_pages=args.num_pages,
                               prefill_chunk=args.prefill_chunk,
                               seed=args.seed)
    else:
        eng = ServeEngine(cfg, params, adapters=adapters,
                          max_batch=args.max_batch, max_len=args.max_len,
                          seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new,
                           adapter_id=i % max(args.adapters, 1),
                           temperature=args.temperature))
    done = eng.run_until_done()
    dt = time.time() - t0
    total_toks = sum(len(r.generated) for r in done.values())
    engine = "paged" if args.paged else "dense"
    print(f"[{engine}] served {len(done)} requests / {total_toks} tokens in "
          f"{dt:.2f}s ({total_toks / dt:.1f} tok/s, {args.adapters} adapters "
          f"hot)")
    if args.paged:
        print(f"  stats: {eng.stats()}")
    for uid in sorted(done)[:4]:
        print(f"  req {uid} adapter={done[uid].adapter_id}: "
              f"{done[uid].generated[:10]}")
    return done


if __name__ == "__main__":
    main()
