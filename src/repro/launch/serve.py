"""Serving launcher: batched multi-adapter LoRA inference.

  # production path: paged arena, chunked prefill, CoW prefix sharing
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --adapters 2 --max-new 16

  # shared-prefix traffic (few prompt families -> high prefix-cache hits)
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 16 --prompt-families 4

  # speculative decoding: n-gram or quantized self-draft drafter
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --spec-decode --draft ngram --spec-k 4

  # tensor-parallel paged decode over N local devices
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --tp 4

  # dense oracle (equivalence baseline only)
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --engine dense
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import lora as lora_lib
from repro.models.transformer import init_params
from repro.serve.api import ParallelConfig, Request, make_engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--adapters", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("paged", "dense"), default="paged",
                    help="paged = production engine; dense = oracle baseline")
    ap.add_argument("--paged", action="store_true",
                    help="deprecated (paged is now the default engine)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size (default: half the dense arena)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable CoW prefix sharing in the paged engine")
    ap.add_argument("--prompt-families", type=int, default=0,
                    help="> 0: draw prompts from N shared-prefix families")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding (paged engine only): draft "
                         "k tokens per slot, verify in one mixed step, "
                         "roll back rejected KV")
    ap.add_argument("--draft", choices=("ngram", "selfdraft"),
                    default="ngram",
                    help="drafter: model-free n-gram lookup, or the target "
                         "model with quantize_params-compressed weights")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per slot per tick")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor parallelism over the first N local devices "
                         "(paged engine only)")
    ap.add_argument("--moe-dispatch", choices=("dropless", "capacity"),
                    default="dropless",
                    help="MoE routing for the paged engine: dropless "
                         "(default; tokens never drop, output invariant to "
                         "prefill chunking) or capacity (training-style "
                         "buckets, baseline comparison only)")
    ap.add_argument("--prefix-cache-path", default=None,
                    help="persist/restore the prefix index at this .npz path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i + 1))
                for i in range(args.adapters)]
    spec = None
    if args.spec_decode:
        if args.engine != "paged":
            raise SystemExit("--spec-decode requires --engine paged")
        from repro.serve.spec import SpecConfig
        spec = SpecConfig(k=args.spec_k, drafter=args.draft)
    if args.engine == "paged":
        eng = make_engine(cfg, params, adapters, mode="paged",
                          max_slots=args.max_batch,
                          max_len=args.max_len,
                          page_size=args.page_size,
                          num_pages=args.num_pages,
                          prefill_chunk=args.prefill_chunk,
                          enable_prefix_cache=not args.no_prefix_cache,
                          spec=spec,
                          parallel=ParallelConfig(tp=args.tp),
                          prefix_cache_path=args.prefix_cache_path,
                          moe_dispatch=args.moe_dispatch,
                          seed=args.seed)
    else:
        if args.tp > 1:
            raise SystemExit("--tp requires --engine paged")
        if args.moe_dispatch != "dropless":
            raise SystemExit("--moe-dispatch capacity requires --engine "
                             "paged (the dense oracle always routes "
                             "dropless)")
        eng = make_engine(cfg, params, adapters, mode="dense",
                          max_batch=args.max_batch, max_len=args.max_len,
                          seed=args.seed)
    rng = np.random.default_rng(args.seed)
    fams = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
            for _ in range(args.prompt_families)]
    t0 = time.time()
    for i in range(args.requests):
        if fams:
            head = fams[i % len(fams)]
            tail = rng.integers(0, cfg.vocab_size,
                                int(rng.integers(2, 8))).astype(np.int32)
            prompt = np.concatenate([head, tail])[:args.max_len - args.max_new
                                                  - 1]
        else:
            plen = int(rng.integers(4, 16))
            prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new,
                           adapter_id=i % max(args.adapters, 1),
                           temperature=args.temperature))
    done = eng.drain()
    dt = time.time() - t0
    total_toks = sum(c.n_tokens for c in done.values())
    print(f"[{args.engine}] served {len(done)} requests / {total_toks} tokens "
          f"in {dt:.2f}s ({total_toks / dt:.1f} tok/s, {args.adapters} "
          f"adapters hot)")
    stats = eng.stats()
    print(f"  stats: {stats.as_dict()}")
    if stats.parallel.tp > 1:
        par = stats.parallel
        print(f"  tp={par.tp} over {list(par.devices)}: "
              f"{par.param_bytes_per_device} param bytes/device, "
              f"{par.kv_bytes_per_device} KV bytes/device")
    if stats.moe.enabled:
        print(f"  moe[{stats.moe.dispatch}]: "
              f"dropped_tokens={stats.moe.dropped_tokens}")
    if args.spec_decode:
        sp = stats.spec
        print(f"  spec[{args.draft} k={args.spec_k}]: "
              f"accept_rate={sp.accept_rate:.2f} "
              f"drafted={sp.drafted_tokens} accepted={sp.accepted_tokens} "
              f"rolled_back={sp.rolled_back_tokens} "
              f"(disabled: {sp.disabled_reason or 'no'})")
    for uid in sorted(done)[:4]:
        print(f"  req {uid} adapter={done[uid].adapter_id} "
              f"[{done[uid].finish_reason}]: {done[uid].tokens[:10]}")
    return done


if __name__ == "__main__":
    main()
