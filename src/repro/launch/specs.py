"""Dry-run cell construction: (arch x shape x mesh) -> (step fn, abstract
input specs with shardings). Nothing here allocates device memory — all
inputs are ShapeDtypeStructs (weak-type-correct, shardable).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, QuantConfig
from repro.configs.shapes import ShapeSuite
from repro.core import lora as lora_lib, quant as quant_lib
from repro.dist import sharding as shd
from repro.models import kvcache, transformer as tfm
from repro.models.transformer import ExecConfig
from repro.optim import adamw
from repro.train import steps as steps_lib


def _specs_from(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def _replicated_specs(shapes_tree, mesh):
    r = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=r),
        shapes_tree)


@dataclass
class Cell:
    name: str
    step: Callable
    args: Tuple[Any, ...]
    meta: Dict[str, Any]


def exec_config_for(cfg: ModelConfig, shape: ShapeSuite, mesh: Mesh,
                    axes: shd.MeshAxes, *, remat: bool = True,
                    attn_impl: str = "auto") -> ExecConfig:
    tp_width = mesh.shape[axes.tp] if axes.tp else 1
    mode = "decode" if shape.kind == "decode" else shape.kind
    dp_total = mesh.size // tp_width
    shard_batch = shape.global_batch % dp_total == 0
    # decode: EP over tp x expert-ff TP over dp — weights never move and
    # the combine einsum stays local (slots-over-all-axes forces a full
    # expert-output all-gather; see EXPERIMENTS.md SSPerf H3)
    moe_parallel = tp_width
    block_q = max(128, shape.seq_len // max(tp_width, 1))
    return ExecConfig(
        attn_impl=attn_impl,
        block_q=block_q,
        block_kv=512,
        remat=(remat and shape.kind == "train"),
        scan_layers=True,
        capacity_factor=None,
        moe_group_size=max(128, shape.seq_len // max(tp_width, 1)),
        act_dtype=jnp.bfloat16,
        sharder=shd.make_sharder(mesh, axes, mode, shard_batch=shard_batch),
        moe_parallel=moe_parallel,
    )


def abstract_params(cfg: ModelConfig, mesh: Mesh, axes: shd.MeshAxes,
                    mode: str, moe_parallel: int,
                    quant_cfg: Optional[QuantConfig] = None,
                    shard_batch: bool = True):
    """ShapeDtypeStruct param tree with production shardings."""
    def build():
        p = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16,
                            moe_parallel=moe_parallel)
        if quant_cfg is not None and quant_cfg.enabled:
            p = quant_lib.quantize_params(p, quant_cfg)
        return p

    shapes = jax.eval_shape(build)
    shardings = shd.params_shardings(cfg, shapes, mesh, axes, mode,
                                     shard_batch=shard_batch)
    return _specs_from(shapes, shardings)


def batch_specs(cfg: ModelConfig, shape: ShapeSuite, mesh: Mesh,
                axes: shd.MeshAxes) -> Dict[str, jax.ShapeDtypeStruct]:
    B, T = shape.global_batch, shape.seq_len
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]
    tok_sh = NamedSharding(mesh, P(dp, axes.tp))
    if cfg.frontend == "tokens":
        data = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32, sharding=tok_sh)}
    else:
        emb_sh = NamedSharding(mesh, P(dp, axes.tp, None))
        data = {"embeds": jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16,
                                               sharding=emb_sh)}
    data["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32, sharding=tok_sh)
    return data


def build_cell(cfg: ModelConfig, shape: ShapeSuite, mesh: Mesh, *,
               quant_cfg: Optional[QuantConfig] = None,
               microbatches: int = 1, attn_impl: str = "auto",
               remat: bool = True, with_lora: bool = True) -> Cell:
    axes = shd.axes_for(mesh)
    ec = exec_config_for(cfg, shape, mesh, axes, remat=remat,
                         attn_impl=attn_impl)
    mode = "decode" if shape.kind == "decode" else shape.kind
    tp_w = mesh.shape[axes.tp] if axes.tp else 1
    sb = shape.global_batch % (mesh.size // tp_w) == 0
    params = abstract_params(cfg, mesh, axes, mode, ec.moe_parallel, quant_cfg,
                             shard_batch=sb)
    lora_shapes = jax.eval_shape(
        functools.partial(lora_lib.init_lora_params, cfg, dtype=jnp.float32),
        jax.random.PRNGKey(0))
    lora_specs = _replicated_specs(lora_shapes, mesh) if with_lora else None
    meta = {"arch": cfg.name, "shape": shape.name, "mesh": tuple(mesh.shape.items()),
            "mode": shape.kind, "fsdp": shd.needs_fsdp(cfg, mesh, axes),
            "quant": quant_cfg.tag if quant_cfg else "bf16",
            "moe_parallel": ec.moe_parallel}

    if shape.kind == "train":
        hp = steps_lib.TrainHParams(microbatches=microbatches)
        raw = steps_lib.make_train_step(cfg, ec, hp)

        def step(params, lora, opt_state, batch, rng_data):
            rng = jax.random.wrap_key_data(rng_data)
            return raw(params, lora, opt_state, batch, rng)

        opt_shapes = jax.eval_shape(adamw.init, lora_shapes)
        opt_specs = _replicated_specs(opt_shapes, mesh)
        rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                        sharding=NamedSharding(mesh, P()))
        args = (params, lora_specs, opt_specs,
                batch_specs(cfg, shape, mesh, axes), rng_spec)
        return Cell(f"{cfg.name}|{shape.name}", step, args, meta)

    if shape.kind == "prefill":
        raw = steps_lib.make_prefill_step(cfg, ec, cache_len=shape.seq_len)
        data = batch_specs(cfg, shape, mesh, axes)
        data.pop("labels")
        args = (params, lora_specs, data)
        return Cell(f"{cfg.name}|{shape.name}", raw, args, meta)

    # decode: one new token against a cache of seq_len
    raw = steps_lib.make_decode_step(cfg, ec)
    B = shape.global_batch
    tp_width = mesh.shape[axes.tp] if axes.tp else 1
    shard_batch = B % (mesh.size // tp_width) == 0
    dp = (axes.dp if len(axes.dp) > 1 else axes.dp[0]) if shard_batch else None
    cache = kvcache.cache_spec_structs(
        cfg, B, shape.seq_len, kv_dtype=jnp.bfloat16,
        sharding_fn=shd.cache_shardings(cfg, mesh, axes,
                                        shard_batch=shard_batch))
    tok_sh = NamedSharding(mesh, P(dp, None))
    if cfg.frontend == "tokens":
        inputs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)}
    else:
        inputs = {"embeds": jax.ShapeDtypeStruct(
            (B, 1, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(dp, None, None)))}
    args = (params, lora_specs, cache, inputs)
    return Cell(f"{cfg.name}|{shape.name}", raw, args, meta)
