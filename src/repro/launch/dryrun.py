import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes; record memory analysis, cost analysis, and the
HLO-derived roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quant M8F8]
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.configs import ALL_SHAPES, ARCH_IDS, SHAPES, cell_supported, get_config
from repro.configs.base import QuantConfig
from repro.launch import mesh as mesh_lib
from repro.launch.specs import build_cell
from repro.roofline.hlo_parse import HloModule

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def parse_quant(tag):
    if not tag or tag == "bf16":
        return None
    m = re.fullmatch(r"M(\d+)F(\d+)", tag)
    assert m, tag
    return QuantConfig(mha_bits=int(m.group(1)), ff_bits=int(m.group(2)))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant_tag: str = "bf16", attn_impl: str = "auto",
             microbatches: int = 1, save: bool = True, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "quant": quant_tag, "attn_impl": attn_impl}
    if not ok:
        rec["status"] = why
        _save(rec, save)
        return rec
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh, quant_cfg=parse_quant(quant_tag),
                          microbatches=microbatches, attn_impl=attn_impl)
        with mesh:  # Mesh context works on jax<0.5 (no jax.set_mesh there)
            lowered = jax.jit(cell.step).lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax<0.5 returns [dict]
            ca = ca[0] if ca else {}
        txt = compiled.as_text()
        hlo_raw = HloModule(txt)
        cost_raw = hlo_raw.entry_cost()
        hlo = HloModule(txt, tpu_dtypes=True)
        cost = hlo.entry_cost()
        # kernelized: flash/wkv interiors VMEM-resident (the Pallas kernels)
        kern = HloModule(txt, tpu_dtypes=True,
                         fused_regions=("flash_fused", "wkv_fused")
                         ).entry_cost()
        rec.update({
            "status": "ok",
            "meta": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in cell.meta.items()},
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
            },
            "xla_cost_once": {"flops": ca.get("flops"),
                              "bytes": ca.get("bytes accessed")},
            "hlo_cost": {
                "flops": cost.flops,
                "bytes": cost.bytes,
                "collective_bytes": cost.coll_bytes,
                "collective_by_kind": cost.coll_by_kind,
            },
            "hlo_cost_kernelized": {
                "flops": kern.flops,
                "bytes": kern.bytes,
                "collective_bytes": kern.coll_bytes,
            },
            "hlo_cost_raw_dtypes": {
                "bytes": cost_raw.bytes,
                "collective_bytes": cost_raw.coll_bytes,
            },
            "parse_warnings": hlo.warnings[:10],
        })
        if verbose:
            mem_gb = rec["memory"]["peak_bytes"] / (1 << 30)
            print(f"[ok] {arch} {shape_name} {mesh_tag} {quant_tag}: "
                  f"compile={t_compile:.1f}s peak={mem_gb:.2f}GiB/dev "
                  f"flops={cost.flops:.3e} bytes={cost.bytes:.3e} "
                  f"coll={cost.coll_bytes:.3e}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[ERR] {arch} {shape_name} {mesh_tag}: {rec['error'][:300]}")
    _save(rec, save)
    return rec


def _save(rec, save):
    if not save:
        return
    d = OUT_DIR / rec["mesh"]
    d.mkdir(parents=True, exist_ok=True)
    tag = "" if rec["quant"] == "bf16" else f"__{rec['quant']}"
    impl = "" if rec.get("attn_impl", "auto") == "auto" else f"__{rec['attn_impl']}"
    path = d / f"{rec['arch']}__{rec['shape']}{tag}{impl}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES] if (args.all or args.shape is None)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_err = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, multi_pod=mp, quant_tag=args.quant,
                               attn_impl=args.attn_impl,
                               microbatches=args.microbatches)
                n_err += rec["status"] == "error"
    print(f"done; {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
