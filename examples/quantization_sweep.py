"""MnFm quantization sweep (paper Fig. 13): pretrain a small base, quantize
crossbar-wise at every MnFm config, LoRA-fine-tune, report perplexity.

    PYTHONPATH=src python examples/quantization_sweep.py
"""
from benchmarks import bench_quant_perplexity

payload = bench_quant_perplexity.run()
print()
print("perplexity by quantization config (lower is better):")
for tag, ppl in payload["ppl"].items():
    print(f"  {tag:6s} {ppl:.3f}")
print("expected ordering (paper Fig. 13): bf16 ~ M8F8 <= M8F4 < M4F4")
