"""End-to-end driver (deliverable b): QLoRA fine-tune a ~100M-param decoder
for a few hundred steps with checkpointing, restart tolerance, and eval.

Presets:
    --preset 100m   12L x d512 x ff2048, vocab 32000 (~92M params) — the
                    full run; several CPU-hours, minutes on one accelerator.
    --preset 10m    (default) 6L x d256, vocab 8192 — CPU-friendly.

    PYTHONPATH=src python examples/finetune_qlora.py --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.configs.base import AttnConfig, LoRAConfig, ModelConfig, QuantConfig
from repro.core import quant
from repro.core.noise import NoiseConfig
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.train.steps import TrainHParams
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    "100m": dict(n_layers=12, d_model=512, n_heads=8, d_ff=2048,
                 vocab_size=32000),
    "10m": dict(n_layers=6, d_model=256, n_heads=4, d_ff=1024,
                vocab_size=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant", default="M8F8")
    ap.add_argument("--noise-sigma", type=float, default=0.01)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_qlora_ckpt")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"qlora-{args.preset}", family="dense",
        n_kv_heads=max(1, p["n_heads"] // 2),
        attn=AttnConfig(pattern=("full",)),
        lora=LoRAConfig(rank=16, alpha=16.0, targets=("wq", "wv")),
        **p).validate()
    print(f"model: {cfg.param_count()/1e6:.0f}M params "
          f"(trainable LoRA: {cfg.lora_param_count()/1e6:.2f}M)")

    base = tfm.init_params(cfg, jax.random.PRNGKey(0))
    if args.quant != "bf16":
        import re
        m = re.fullmatch(r"M(\d+)F(\d+)", args.quant)
        base = quant.quantize_params(
            base, QuantConfig(mha_bits=int(m.group(1)),
                              ff_bits=int(m.group(2))), min_size=1)
        print(f"base quantized crossbar-wise ({args.quant})")

    ds = SyntheticLM(cfg.vocab_size, seed=0)
    ec = tfm.ExecConfig(noise=NoiseConfig(enabled=args.noise_sigma > 0,
                                          sigma_rel=args.noise_sigma))
    tc = TrainerConfig(
        seq_len=args.seq, global_batch=args.batch, steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(50, args.steps // 5),
        log_every=20,
        hparams=TrainHParams(
            microbatches=2,
            adamw=AdamWConfig(lr=3e-3,
                              schedule=warmup_cosine(args.steps // 10,
                                                     args.steps))))
    trainer = Trainer(cfg, tc, ds, exec_cfg=ec, params=base)
    trainer.maybe_restore()
    log = trainer.run_with_restarts()

    # eval perplexity on held-out batches
    nll = []
    for i in range(5):
        b = ds.batch(10_000 + i, 16, args.seq)
        lg, _, _ = tfm.forward(cfg, base, {"tokens": jnp.asarray(b["tokens"])},
                               lora=trainer.lora, mode="train")
        nll.append(float(tfm.lm_loss(cfg, lg, jnp.asarray(b["labels"]))[0]))
    print(f"train loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}; "
          f"eval ppl {np.exp(np.mean(nll)):.2f} "
          f"(corpus floor ~{np.exp(ds.entropy_bound()):.2f})")


if __name__ == "__main__":
    main()
