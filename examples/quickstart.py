"""Quickstart: QLoRA fine-tuning + serving in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import quant
from repro.data.pipeline import SyntheticLM
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig
from repro.serve.api import Request, make_engine
from repro.train.steps import TrainHParams
from repro.train.trainer import Trainer, TrainerConfig

# 1. a small config (same structure as the full llama3.2-1b)
cfg = reduce_config(get_config("llama3.2-1b"), d_model=128, n_heads=4,
                    d_ff=256)

# 2. crossbar-wise quantize the frozen base (the paper's M8F8)
base = init_params(cfg, jax.random.PRNGKey(0))
base = quant.quantize_params(base, QuantConfig(mha_bits=8, ff_bits=8),
                             min_size=1)

# 3. LoRA fine-tune on a synthetic bigram corpus
ds = SyntheticLM(cfg.vocab_size, seed=0)
tc = TrainerConfig(seq_len=64, global_batch=16, steps=100, log_every=25,
                   hparams=TrainHParams(adamw=AdamWConfig(lr=5e-3)))
trainer = Trainer(cfg, tc, ds, params=base)
log = trainer.run()
print(f"loss: {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")

# 4. serve with the trained adapter (paged engine, dropless MoE dispatch)
eng = make_engine(cfg, base, adapters=[trainer.lora], max_slots=2, max_len=64)
eng.submit(Request(uid=0, prompt=np.array([5, 17, 23]), max_new_tokens=8))
done = eng.drain()
print("generated:", list(done[0].tokens))
