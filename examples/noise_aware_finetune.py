"""Noise-aware fine-tuning (paper SS V.E / Fig. 9): train LoRA adapters with
Gaussian noise injected into the frozen base so deployment on non-ideal
crossbars doesn't cost accuracy.

    PYTHONPATH=src python examples/noise_aware_finetune.py
"""
from benchmarks import bench_noise

payload = bench_noise.run()
print()
print(f"sigma = {payload['sigma_rel']} x absmax")
print(f"ideal accuracy        : {payload['ideal_acc']:.4f}")
print(f"naive  (clean-trained): {payload['naive_acc']:.4f}  "
      f"(gap {payload['gap_naive_pct']:.2f}pp)")
print(f"noise-aware           : {payload['noise_aware_acc']:.4f}  "
      f"(gap {payload['gap_aware_pct']:.2f}pp)")
print("paper claim: noise-aware recovers to <0.5% of ideal")
