"""Batched multi-adapter serving (paper SS V.G): one frozen quantized base,
several LoRA adapters hot simultaneously, continuous batching over a PAGED
KV arena — admission is bounded by page occupancy, prompts prefill in
bucketed chunks, and one jitted mixed step serves prefill + decode rows.

    PYTHONPATH=src python examples/serve_multiadapter.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import lora as lora_lib, quant
from repro.models.transformer import init_params
from repro.serve.engine import PagedServeEngine, Request

cfg = reduce_config(get_config("mistral-nemo-12b"), d_model=128, n_heads=4)
key = jax.random.PRNGKey(0)
base = quant.quantize_params(init_params(cfg, key),
                             QuantConfig(mha_bits=8, ff_bits=8), min_size=1)

# three "tasks" = three adapters (in production: one per fine-tuned domain)
adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
            for i in range(3)]
eng = PagedServeEngine(cfg, base, adapters=adapters, max_slots=4, max_len=96,
                       page_size=8, prefill_chunk=8)

rng = np.random.default_rng(0)
t0 = time.time()
for i in range(10):
    eng.submit(Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size, rng.integers(3, 12)).astype(np.int32),
        max_new_tokens=12,
        adapter_id=i % 3,
        temperature=0.8 if i % 2 else 0.0))
done = eng.run_until_done()
dt = time.time() - t0
total = sum(len(r.generated) for r in done.values())
print(f"{len(done)} requests / {total} tokens in {dt:.2f}s "
      f"({total/dt:.1f} tok/s) with 3 adapters hot")
print(f"engine stats: {eng.stats()}")
for uid in sorted(done):
    r = done[uid]
    print(f"  req {uid} adapter={r.adapter_id} temp={r.temperature}: "
          f"{r.generated}")
