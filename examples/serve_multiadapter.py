"""Batched multi-adapter serving (paper SS V.G): one frozen quantized base,
several LoRA adapters hot simultaneously, continuous batching over a PAGED
KV arena — admission is bounded by page occupancy, prompts prefill in
bucketed chunks, one jitted mixed step serves prefill + decode rows, and
requests sharing a prompt prefix (same adapter) map the same KV pages via
the copy-on-write prefix cache instead of recomputing them.

    PYTHONPATH=src python examples/serve_multiadapter.py

Speculative decoding rides on the same engine (--spec-decode): a drafter
guesses up to --spec-k tokens per slot, the mixed step verifies them all
at once, and rejected tokens roll the paged KV write cursor back:

    PYTHONPATH=src python examples/serve_multiadapter.py --spec-decode \
        --draft selfdraft --spec-k 4

Tensor parallelism is one knob away (--tp N shards the model and the paged
KV pool over the first N local devices; tokens stay identical):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/serve_multiadapter.py --tp 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.configs.base import QuantConfig
from repro.core import lora as lora_lib, quant
from repro.models.transformer import init_params
from repro.serve.api import ParallelConfig, Request, make_engine
from repro.serve.spec import SpecConfig

ap = argparse.ArgumentParser()
ap.add_argument("--spec-decode", action="store_true",
                help="draft-and-verify decoding with paged-KV rollback")
ap.add_argument("--draft", choices=("ngram", "selfdraft"), default="ngram",
                help="model-free n-gram lookup, or the target model with "
                     "quantize_params-compressed weights as its own drafter")
ap.add_argument("--spec-k", type=int, default=4,
                help="max draft tokens per slot per tick")
ap.add_argument("--tp", type=int, default=1,
                help="tensor parallelism over the first N local devices")
args = ap.parse_args()

cfg = reduce_config(get_config("mistral-nemo-12b"), d_model=128, n_heads=4)
key = jax.random.PRNGKey(0)
base = quant.quantize_params(init_params(cfg, key),
                             QuantConfig(mha_bits=8, ff_bits=8), min_size=1)

# three "tasks" = three adapters (in production: one per fine-tuned domain)
adapters = [lora_lib.init_lora_params(cfg, jax.random.fold_in(key, i))
            for i in range(3)]
spec = (SpecConfig(k=args.spec_k, drafter=args.draft)
        if args.spec_decode else None)
eng = make_engine(cfg, base, adapters, mode="paged", max_slots=4, max_len=96,
                  page_size=8, prefill_chunk=8, spec=spec,
                  parallel=ParallelConfig(tp=args.tp))

# shared system-prompt prefix per adapter, unique user tail per request —
# the common case the prefix cache exists for
rng = np.random.default_rng(0)
system = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
          for _ in range(3)]
t0 = time.time()
for i in range(10):
    tail = rng.integers(0, cfg.vocab_size,
                        int(rng.integers(3, 8))).astype(np.int32)
    eng.submit(Request(
        uid=i,
        prompt=np.concatenate([system[i % 3], tail]),
        max_new_tokens=12,
        adapter_id=i % 3,
        temperature=0.8 if i % 2 else 0.0))
done = eng.drain()
dt = time.time() - t0
total = sum(c.n_tokens for c in done.values())
stats = eng.stats()
print(f"{len(done)} requests / {total} tokens in {dt:.2f}s "
      f"({total/dt:.1f} tok/s) with 3 adapters hot")
print(f"prefix cache: {stats.prefix_cache.hit_tokens} prompt tokens served "
      f"from resident pages ({stats.prefix_cache.hits} hits, "
      f"{stats.scheduler.cow_forks} CoW forks)")
if stats.parallel.tp > 1:
    print(f"tensor parallel: tp={stats.parallel.tp}, "
          f"{stats.parallel.kv_bytes_per_device} KV bytes per device")
if args.spec_decode:
    sp = stats.spec
    print(f"spec decode [{args.draft} k={args.spec_k}]: "
          f"accept_rate={sp.accept_rate:.2f} "
          f"({sp.accepted_tokens}/{sp.drafted_tokens} drafts survived, "
          f"{sp.rolled_back_tokens} rolled back, "
          f"{stats.scheduler.rolled_back_pages} pages reclaimed)"
          + (f" [DISABLED: {sp.disabled_reason}]"
             if sp.disabled_reason else ""))
print(f"engine stats: {stats.as_dict()}")
for uid in sorted(done):
    c = done[uid]
    print(f"  req {uid} adapter={c.adapter_id} [{c.finish_reason}]: "
          f"{list(c.tokens)}")
